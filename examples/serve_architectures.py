"""Batched prefill+decode across architecture families (deliverable b/f):
dense GQA, MoE+SWA, Mamba2 hybrid, xLSTM, encoder-decoder, VLM — all via
the same prefill/decode_step API, at reduced size on CPU.

  PYTHONPATH=src python examples/serve_architectures.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import transformer as T

ARCHS = ["phi4-mini-3.8b", "mixtral-8x7b", "zamba2-1.2b", "xlstm-125m",
         "whisper-large-v3", "llama-3.2-vision-90b"]


def main():
    key = jax.random.PRNGKey(0)
    b, p, new = 2, 8, 12
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        params = T.init_params(cfg, key)
        prompt = jax.random.randint(key, (b, p), 0, cfg.vocab)
        aux = None
        if cfg.family == "vlm":
            aux = {"vision": jnp.zeros((b, cfg.n_vision_tokens,
                                        cfg.d_model), jnp.bfloat16)}
        if cfg.is_encoder_decoder:
            aux = {"frames": jnp.zeros((b, 2 * p, cfg.d_model),
                                       jnp.bfloat16)}
        t0 = time.time()
        _, cache = T.prefill(cfg, params, prompt, aux, cache_len=p + new)
        tok = prompt[:, -1:]
        decode = jax.jit(lambda pr, c, t: T.decode_step(cfg, pr, c, t))
        out = []
        for i in range(new):
            logits, cache = decode(params, cache, tok)
            tok = jnp.argmax(logits, -1)[:, None]
            out.append(int(tok[0, 0]))
        dt = time.time() - t0
        print(f"{arch:24s} [{cfg.family:6s}] {b * new / dt:6.1f} tok/s "
              f"greedy={out[:8]}")


if __name__ == "__main__":
    main()
