"""RQ3 (paper Fig. 4): trace the helpfulness-harmlessness trade-off by
sweeping FIRM's preference vector p (Eq. 3: Diag(p^-1) regularizer).

  PYTHONPATH=src python examples/preference_pareto.py
"""
import numpy as np

from repro.configs import get_config
from repro.configs.base import FIRMConfig
from repro.fed.engine import EngineConfig, FederatedTrainer

ROUNDS = 3


def run_with_preference(p):
    cfg = get_config("llama-3.2-1b").reduced(n_layers=2, d_model=128,
                                             vocab=512)
    fc = FIRMConfig(n_objectives=2, n_clients=2, local_steps=1,
                    batch_size=4, beta=0.01, preference=p)
    tr = FederatedTrainer(cfg, fc, EngineConfig(max_new=16, prompt_len=8,
                                                seed=3))
    hist = tr.run(ROUNDS)
    return hist[-1]


def main():
    print("preference(help,harm) -> final rewards, mean lambda")
    for p0 in (0.25, 0.5, 1.0, 2.0, 4.0):
        p = (p0, round(1.0 / p0, 4))
        s = run_with_preference(p)
        print(f"  p={p}: rewards={np.round(s['rewards'], 3).tolist()} "
              f"lambda={np.round(s['lam_mean'], 3).tolist()}")
    print("higher p_help -> larger lambda_help -> descent direction tilts "
          "toward helpfulness (paper Fig. 4).")


if __name__ == "__main__":
    main()
