"""Reward-vs-uplink-bytes Pareto sweep over the comms codec presets.

Runs the same smoke-scale FIRM alignment job under each deployment
profile in ``configs.base.CODEC_PRESETS`` and prints the measured wire
bytes next to the attained rewards — the operating-point menu a
bandwidth-constrained federated deployment picks from.

Uses the declarative front door (``repro.fed.api``): each profile is a
``RunSpec``, ``plan()`` resolves the executor and predicts the exact
wire bytes BEFORE anything compiles (the "plan/round" line), and
``execute`` runs it — the predicted bytes match the measured ledger
exactly because every codec's ``nbytes_static`` equals its measured
``Payload.nbytes``.

  PYTHONPATH=src python examples/codec_pareto.py
"""
import numpy as np

from repro.configs import get_config
from repro.configs.base import CODEC_PRESETS, FIRMConfig
from repro.fed import api
from repro.fed.api import EngineConfig, RunSpec


def main():
    cfg = get_config("llama-3.2-1b").reduced(n_layers=2, d_model=64,
                                             vocab=256)
    fc = FIRMConfig(n_objectives=2, n_clients=2, local_steps=1,
                    batch_size=2, beta=0.05)
    rounds = 2
    print(f"{'profile':<10} {'uplink':<14} {'downlink':<9} "
          f"{'up_KB':>7} {'down_KB':>8} {'ratio':>6}  rewards")
    base_up = None
    for profile, (up, down) in CODEC_PRESETS.items():
        spec = RunSpec(
            model=cfg, firm=fc,
            engine=EngineConfig(max_new=6, prompt_len=4, uplink_codec=up,
                                downlink_codec=down),
            rounds=rounds)
        plan = api.plan(spec)
        s = plan.execute()[-1]
        if base_up is None:
            base_up = s["up_bytes"]
        print(f"{profile:<10} {up:<14} {down:<9} "
              f"{s['up_bytes'] / 1e3:>7.1f} {s['down_bytes'] / 1e3:>8.1f} "
              f"{s['up_bytes'] / base_up:>6.2f}  "
              f"{np.round(s['rewards'], 3).tolist()}")
        print(f"{'':<10} plan/round ({plan.executor}): "
              f"up {plan.up_bytes_per_round / 1e3:.1f}KB "
              f"down {plan.down_bytes_per_round / 1e3:.1f}KB"
              + ("  [matches measured]"
                 if plan.up_bytes_per_round * rounds == s["up_bytes"]
                 else "  [MISMATCH]"))
    print("\nuplink ratio < 0.30 for every coded profile — the O(Cd) "
          "claim survives an actual wire format (see ISSUE acceptance).")


if __name__ == "__main__":
    main()
