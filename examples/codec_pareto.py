"""Reward-vs-uplink-bytes Pareto sweep over the comms codec presets.

Runs the same smoke-scale FIRM alignment job under each deployment
profile in ``configs.base.CODEC_PRESETS`` and prints the measured wire
bytes next to the attained rewards — the operating-point menu a
bandwidth-constrained federated deployment picks from.

  PYTHONPATH=src python examples/codec_pareto.py
"""
import numpy as np

from repro.configs import get_config
from repro.configs.base import CODEC_PRESETS, FIRMConfig
from repro.core import comms as comms_lib
from repro.fed.engine import EngineConfig, FederatedTrainer


def main():
    cfg = get_config("llama-3.2-1b").reduced(n_layers=2, d_model=64,
                                             vocab=256)
    fc = FIRMConfig(n_objectives=2, n_clients=2, local_steps=1,
                    batch_size=2, beta=0.05)
    rounds = 2
    print(f"{'profile':<10} {'uplink':<14} {'downlink':<9} "
          f"{'up_KB':>7} {'down_KB':>8} {'ratio':>6}  rewards")
    base_up = None
    for profile, (up, down) in CODEC_PRESETS.items():
        ec = EngineConfig(max_new=6, prompt_len=4, uplink_codec=up,
                          downlink_codec=down)
        tr = FederatedTrainer(cfg, fc, ec)
        s = tr.run(rounds)[-1]
        if base_up is None:
            base_up = s["up_bytes"]
        print(f"{profile:<10} {up:<14} {down:<9} "
              f"{s['up_bytes'] / 1e3:>7.1f} {s['down_bytes'] / 1e3:>8.1f} "
              f"{s['up_bytes'] / base_up:>6.2f}  "
              f"{np.round(s['rewards'], 3).tolist()}")
        analytic = comms_lib.firm_round_bytes_codec(
            tr.d_trainable, fc.n_clients, uplink_codec=up,
            downlink_codec=down)
        print(f"{'':<10} analytic/round: up {analytic['up'] / 1e3:.1f}KB "
              f"down {analytic['down'] / 1e3:.1f}KB")
    print("\nuplink ratio < 0.30 for every coded profile — the O(Cd) "
          "claim survives an actual wire format (see ISSUE acceptance).")


if __name__ == "__main__":
    main()
