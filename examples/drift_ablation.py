"""RQ2 (paper Fig. 3): multi-objective disagreement drift with and
without FIRM's regularization (beta = 0 vs beta > 0).

  PYTHONPATH=src python examples/drift_ablation.py
"""
import numpy as np

from repro.configs import get_config
from repro.configs.base import FIRMConfig
from repro.fed.engine import EngineConfig, FederatedTrainer

ROUNDS = 4


def run(algorithm):
    cfg = get_config("llama-3.2-1b").reduced(n_layers=2, d_model=128,
                                             vocab=512)
    fc = FIRMConfig(n_objectives=2, n_clients=2, local_steps=1,
                    batch_size=4, beta=0.05)
    tr = FederatedTrainer(cfg, fc, EngineConfig(algorithm=algorithm,
                                                max_new=16, prompt_len=8,
                                                seed=7))
    return tr.run(ROUNDS)


def main():
    for name, alg in (("FIRM beta=0.05", "firm"),
                      ("unregularized beta=0", "firm_unreg")):
        hist = run(alg)
        drift = [round(h["lam_disagreement"], 4) for h in hist]
        print(f"{name}:")
        print(f"  per-round lambda disagreement: {drift}")
        print(f"  final rewards: {np.round(hist[-1]['rewards'], 3).tolist()}")
    print("beta > 0 keeps client lambda trajectories consistent "
          "(paper Fig. 3c/3d).")


if __name__ == "__main__":
    main()
