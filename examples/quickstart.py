"""Quickstart: one federated FIRM alignment round in ~a minute on CPU.

Runs the paper's Algorithm 1 end-to-end on a reduced Llama-3.2-family
model: C clients sample prompts, generate responses, score them with two
conflicting reward models (helpfulness / harmlessness), compute M PPO
gradients, resolve them locally with the beta-regularized MGDA QP, and the
server FedAvg-aggregates the LoRA adapters.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs import get_config
from repro.configs.base import FIRMConfig
from repro.fed.engine import EngineConfig, FederatedTrainer


def main():
    cfg = get_config("llama-3.2-1b").reduced(n_layers=2, d_model=128,
                                             vocab=512)
    fc = FIRMConfig(n_objectives=2, n_clients=2, local_steps=2,
                    batch_size=4, beta=0.01)
    trainer = FederatedTrainer(cfg, fc, EngineConfig(max_new=16,
                                                     prompt_len=8))
    print(f"model={cfg.name}  C={fc.n_clients}  K={fc.local_steps}  "
          f"beta={fc.beta}  adapters={trainer.d_trainable:,} params")
    for r in range(3):
        s = trainer.run_round()
        print(f"round {r + 1}: rewards(help,harm)="
              f"{np.round(s['rewards'], 3).tolist()}  "
              f"lambda={np.round(s['lam_mean'], 3).tolist()}  "
              f"drift={s['lam_disagreement']:.4f}  "
              f"comm={s['comm_bytes'] / 1e6:.1f}MB")
    print("done — the same API scales to every config in repro/configs "
          "(see launch/train.py and the multi-pod dry-run); pass "
          "EngineConfig(uplink_codec='int8+ef') to compress the uplink "
          "~4x (examples/codec_pareto.py sweeps the codec registry).")


if __name__ == "__main__":
    main()
