"""Regenerate tests/golden_plans.json from the test_plan config matrix.

  PYTHONPATH=src python scripts/update_golden_plans.py

Review the diff before committing: the golden file is the fast-lane
guard against silent executor regressions (a config quietly falling
back to the per-client loop shows up as an `executor` change here).
"""
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from repro.fed import api                       # noqa: E402
from tests.test_plan import GOLDEN, golden_matrix   # noqa: E402


def main():
    summaries = {name: api.plan(spec).summary()
                 for name, spec in golden_matrix().items()}
    GOLDEN.write_text(json.dumps(summaries, indent=2, sort_keys=True)
                      + "\n")
    print(f"wrote {GOLDEN} ({len(summaries)} plans)")


if __name__ == "__main__":
    main()
