"""Render the EXPERIMENTS.md §Roofline table from runs/dryrun.json."""
import json
import sys

path = sys.argv[1] if len(sys.argv) > 1 else "runs/dryrun.json"
recs = json.load(open(path))

print("| arch | shape | mesh | compute_s | memory_s | collective_s | "
      "dominant | 6ND/HLO | temp GB/dev |")
print("|---|---|---|---|---|---|---|---|---|")
for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
    if r["status"] == "skipped":
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
              f"skipped | — | — |")
        continue
    if r["status"] != "ok":
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR | | | "
              f"| | |")
        continue
    rf = r["roofline"]
    print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
          f"| {rf['compute_s']:.4g} | {rf['memory_s']:.4g} "
          f"| {rf['collective_s']:.4g} "
          f"| **{r['dominant_term'].replace('_s','')}** "
          f"| {r['useful_flop_ratio']:.2f} "
          f"| {r['memory']['temp_bytes']/1e9:.1f} |")
