"""Property + unit tests for the regularized MGDA core (paper Eq. 1-3,
App. A/H, Lemma F.6).  (Hypothesis property sweeps live in
test_properties_hypothesis.py so this module collects without it.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import drift, mgda


def rand_psd(key, m, scale=1.0):
    a = jax.random.normal(key, (m, m + 2)) * scale
    return a @ a.T


# ------------------------------------------------------------- projection
@pytest.mark.parametrize("v,want", [
    ([0.3, 0.7], [0.3, 0.7]),                  # already on the simplex
    ([2.0, 0.0], [1.0, 0.0]),                  # clamps to a vertex
    ([-5.0, -5.0, -5.0], [1 / 3] * 3),         # ties project to uniform
    ([10.0, 0.2, 0.1], [1.0, 0.0, 0.0]),
])
def test_project_simplex_known_cases(v, want):
    """Deterministic twin of the hypothesis projection sweep."""
    p = np.asarray(mgda.project_simplex(jnp.asarray(v, jnp.float32)))
    np.testing.assert_allclose(p, want, atol=1e-5)
    assert abs(p.sum() - 1.0) < 1e-5
    assert (p >= -1e-7).all()


# ----------------------------------------------------------------- solvers
@pytest.mark.parametrize("m", [2, 3, 4])
def test_pgd_beats_grid(m):
    key = jax.random.PRNGKey(m)
    Q = rand_psd(key, m) + 0.05 * jnp.eye(m)
    lam = mgda.solve_qp_pgd(Q, iters=500)
    f_star = float(lam @ Q @ lam)
    # compare against a simplex grid
    grid = np.random.RandomState(0).dirichlet(np.ones(m), size=500)
    f_grid = np.einsum("bi,ij,bj->b", grid, np.asarray(Q), grid).min()
    assert f_star <= f_grid + 1e-4


def test_closed_form_m2_matches_pgd():
    for seed in range(10):
        Q = rand_psd(jax.random.PRNGKey(seed), 2) + 0.01 * jnp.eye(2)
        l1 = mgda.solve_qp_m2(Q)
        l2 = mgda.solve_qp_pgd(Q, iters=2000)
        f1 = float(l1 @ Q @ l1)
        f2 = float(l2 @ Q @ l2)
        assert abs(f1 - f2) < 1e-4, (seed, f1, f2)


def test_frank_wolfe_matches_pgd():
    for seed in range(5):
        Q = rand_psd(jax.random.PRNGKey(seed), 3) + 0.05 * jnp.eye(3)
        l1 = mgda.solve_qp_frank_wolfe(Q, iters=500)
        l2 = mgda.solve_qp_pgd(Q, iters=2000)
        assert abs(float(l1 @ Q @ l1) - float(l2 @ Q @ l2)) < 1e-3


# ----------------------------------------------------------- regularization
def test_trace_normalization():
    G = jnp.diag(jnp.asarray([100.0, 300.0]))
    Q = mgda.regularize(G, beta=0.0, trace_normalize=True)
    np.testing.assert_allclose(float(jnp.trace(Q)), 2.0, rtol=1e-5)


def test_beta_infinity_gives_uniform():
    G = rand_psd(jax.random.PRNGKey(0), 3)
    lam = mgda.solve(G, beta=1e6, trace_normalize=True, iters=500)
    np.testing.assert_allclose(np.asarray(lam), np.ones(3) / 3, atol=1e-3)


def test_beta_improves_conditioning():
    g = jnp.asarray([[1.0, 0.0], [1.0, 1e-4]])  # nearly parallel gradients
    G = g @ g.T
    c0 = np.linalg.cond(np.asarray(mgda.regularize(G, 0.0,
                                                   trace_normalize=True)))
    c1 = np.linalg.cond(np.asarray(mgda.regularize(G, 0.1,
                                                   trace_normalize=True)))
    assert c1 < c0


def test_preference_monotone():
    """Higher preference p_j -> larger weight lambda_j (Eq. 3)."""
    G = rand_psd(jax.random.PRNGKey(3), 2) + 0.1 * jnp.eye(2)
    lam_lo = mgda.solve(G, 0.0, preference=jnp.asarray([0.5, 2.0]),
                        iters=500)
    lam_hi = mgda.solve(G, 0.0, preference=jnp.asarray([2.0, 0.5]),
                        iters=500)
    assert float(lam_hi[0]) > float(lam_lo[0])


# ------------------------------------------------------ disagreement drift
def test_lambda_solution_stability_in_beta():
    """Sensitivity of lambda* to gradient noise decreases with beta
    (the paper's core stabilisation claim, Rmk 4.8)."""
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (2, 64))
    g = g.at[1].set(g[0] + 0.01 * jax.random.normal(jax.random.fold_in(key, 1),
                                                    (64,)))

    def spread(beta):
        lams = []
        for i in range(12):
            noise = 0.05 * jax.random.normal(jax.random.fold_in(key, 100 + i),
                                             g.shape)
            G = mgda.gram_matrix(g + noise)
            lams.append(mgda.solve(G, beta, iters=300))
        lams = jnp.stack(lams)
        return float(drift.lambda_disagreement(lams)["pairwise_mean"])

    assert spread(1.0) < spread(0.0)


def test_lemma_f6_bound():
    """||lam_c - lam_c'|| <= (4RM/beta) max_j ||g_j^c - g_j^c'|| for the
    UNNORMALISED regularized problem (Lemma F.6)."""
    key = jax.random.PRNGKey(7)
    m, d, beta = 3, 128, 0.5
    for i in range(10):
        k1, k2 = jax.random.split(jax.random.fold_in(key, i))
        g1 = [0.1 * jax.random.normal(jax.random.fold_in(k1, j), (d,))
              for j in range(m)]
        g2 = [a + 0.01 * jax.random.normal(jax.random.fold_in(k2, j), (d,))
              for j, a in enumerate(g1)]
        lam1 = mgda.solve(mgda.gram_matrix(g1), beta,
                          trace_normalize=False, iters=800)
        lam2 = mgda.solve(mgda.gram_matrix(g2), beta,
                          trace_normalize=False, iters=800)
        chk = drift.lemma_f6_check(g1, g2, lam1, lam2, beta)
        assert float(chk["lhs"]) <= float(chk["rhs"]) + 1e-5


def test_combine_matches_manual():
    key = jax.random.PRNGKey(0)
    grads = [{"a": jax.random.normal(jax.random.fold_in(key, j), (5,))}
             for j in range(3)]
    lam = jnp.asarray([0.2, 0.3, 0.5])
    out = mgda.combine(grads, lam)
    manual = sum(float(lam[j]) * np.asarray(grads[j]["a"]) for j in range(3))
    np.testing.assert_allclose(np.asarray(out["a"]), manual, rtol=1e-5)


def test_gram_matrix_pytrees_vs_stacked():
    key = jax.random.PRNGKey(1)
    flat = jax.random.normal(key, (3, 50))
    trees = [{"x": flat[j, :30], "y": flat[j, 30:]} for j in range(3)]
    np.testing.assert_allclose(np.asarray(mgda.gram_matrix(trees)),
                               np.asarray(mgda.gram_matrix(flat)), rtol=1e-5)
