"""Comms codec subsystem: Pallas kernels vs jnp oracles, codec roundtrips,
measured byte accounting, error feedback, registry, engine integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comms import (ErrorFeedback, IdentityCodec, LowRankCodec,
                         QuantizeCodec, TopKCodec, flat_to_tree, make_codec,
                         tree_to_flat)
from repro.core import comms
from repro.kernels import ops, ref
from repro.kernels.quantize import _DET_BITS

KEY = jax.random.PRNGKey(0)


def _tree(seed=0, scale=1.0):
    k = jax.random.PRNGKey(seed)
    return {"a": scale * jax.random.normal(k, (300, 16)),
            "b": {"c": scale * jax.random.normal(
                jax.random.fold_in(k, 1), (16, 64))}}


# ----------------------------------------------------- kernels vs oracles
@pytest.mark.parametrize("qmax", [127, 7])
@pytest.mark.parametrize("stochastic", [True, False])
@pytest.mark.parametrize("rows", [1, 5, 37])
def test_quantize_pallas_matches_ref(qmax, stochastic, rows):
    x = jax.random.normal(jax.random.fold_in(KEY, rows), (rows, 1024))
    if stochastic:
        bits = jax.random.bits(jax.random.fold_in(KEY, 1), x.shape,
                               jnp.uint32)
    else:
        bits = jnp.full(x.shape, _DET_BITS, jnp.uint32)
    cp, sp = ops.quantize(x, bits, qmax)                 # Pallas interpret
    cr, sr = ref.quantize(x, bits, qmax)                 # jnp oracle
    assert (np.asarray(cp) == np.asarray(cr)).all()
    np.testing.assert_allclose(np.asarray(sp), np.asarray(sr), rtol=1e-6)
    dq_p = ops.dequantize(cp, sp)
    dq_r = ref.dequantize(cr, sr)
    np.testing.assert_allclose(np.asarray(dq_p), np.asarray(dq_r),
                               rtol=1e-6, atol=1e-7)
    # per-element error bound: one quantization step
    err = np.abs(np.asarray(dq_p) - np.asarray(x))
    assert (err <= np.asarray(sp) + 1e-6).all()


def test_quantize_deterministic_rounds_to_nearest():
    x = jnp.asarray([[0.0, 0.24, 0.26, -0.26, 1.0] + [0.0] * 1019])
    bits = jnp.full(x.shape, _DET_BITS, jnp.uint32)
    codes, scales = ref.quantize(x, bits, qmax=2)        # scale = 0.5
    got = np.asarray(codes[0, :5])
    np.testing.assert_array_equal(got, [0, 0, 1, -1, 2])


def test_stochastic_rounding_unbiased():
    """Mean of many stochastic quantizations converges to the input."""
    x = jnp.full((1, 1024), 0.35)
    x = x.at[0, 0].set(1.0)                              # pins scale
    acc = np.zeros((1, 1024))
    n = 200
    for s in range(n):
        bits = jax.random.bits(jax.random.fold_in(KEY, s), x.shape,
                               jnp.uint32)
        c, sc = ref.quantize(x, bits, qmax=7)
        acc += np.asarray(ref.dequantize(c, sc))
    np.testing.assert_allclose(acc[0, 1:] / n, 0.35, atol=0.02)


@pytest.mark.parametrize("thresh", [0.0, 0.5, 1.5])
def test_threshold_ops_pallas_match_ref(thresh):
    x = jax.random.normal(KEY, (37, 1024))
    np.testing.assert_allclose(
        float(ops.abs_threshold_count(x, jnp.float32(thresh))),
        float(ref.abs_threshold_count(x, thresh)))
    np.testing.assert_allclose(
        np.asarray(ops.abs_threshold_mask(x, jnp.float32(thresh))),
        np.asarray(ref.abs_threshold_mask(x, thresh)))


def test_topk_threshold_bisection_brackets_k():
    x = jax.random.normal(KEY, (11, 1024))
    for k in (1, 64, 2000):
        lo, hi = ops.topk_threshold(x, k)
        cnt_lo = float(ref.abs_threshold_count(x, lo))
        cnt_hi = float(ref.abs_threshold_count(x, hi))
        assert cnt_hi < k <= cnt_lo, (k, cnt_lo, cnt_hi)


def test_topk_support_pallas_matches_lax_topk():
    from repro.comms.sparsify import topk_support
    flat = jax.random.normal(KEY, (5000,))
    for k in (1, 250):
        ip, vp = topk_support(flat, k, use_pallas=True)
        ir, vr = topk_support(flat, k, use_pallas=False)
        np.testing.assert_array_equal(np.asarray(ip), np.asarray(ir))
        np.testing.assert_allclose(np.asarray(vp), np.asarray(vr))


def test_topk_support_ties_keep_largest():
    """Boundary ties must never evict a strictly larger entry: k-1 tied
    0.5s at low indices + one 5.0 at the end — the 5.0 must survive."""
    from repro.comms.sparsify import topk_support
    k = 8
    flat = jnp.zeros((4096,)).at[:k - 1].set(0.5).at[20:40].set(0.5)
    flat = flat.at[-1].set(5.0)
    idx, vals = topk_support(flat, k, use_pallas=True)
    assert 4095 in np.asarray(idx)
    assert float(vals[np.asarray(idx) == 4095][0]) == 5.0
    # every selected value is a 0.5-tie or the 5.0, never a zero
    assert (np.abs(np.asarray(vals)) >= 0.5).all()


def test_topk_support_fewer_nonzeros_than_k():
    """With m < k nonzeros the decoded vector must keep all of them
    (the old first-k-by-index path returned all zeros here)."""
    from repro.comms.sparsify import topk_support
    flat = jnp.zeros((4096,)).at[4092:].set(
        jnp.asarray([1.0, 2.0, 3.0, 4.0]))
    idx, vals = topk_support(flat, 16, use_pallas=True)
    dense = np.zeros(4096)
    dense[np.asarray(idx)] = np.asarray(vals)
    np.testing.assert_array_equal(dense[4092:], [1.0, 2.0, 3.0, 4.0])
    assert np.abs(dense[:4092]).sum() == 0.0


# -------------------------------------------------------- codec roundtrip
def test_flatten_roundtrip_preserves_tree():
    tree = _tree()
    flat, spec = tree_to_flat(tree)
    back = flat_to_tree(flat, spec)
    for a, b in zip(jax.tree_util.tree_leaves(back),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_identity_codec_exact_and_f32_bytes():
    tree = _tree()
    flat, _ = tree_to_flat(tree)
    codec = IdentityCodec()
    payload, _ = codec.encode(tree)
    assert payload.nbytes == 4 * flat.size
    back = codec.decode(payload)
    for a, b in zip(jax.tree_util.tree_leaves(back),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("spec,max_ratio,max_rel_err", [
    ("int8", 0.30, 0.03),
    ("int4", 0.16, 0.30),
    ("topk:0.05", 0.11, 1.0),
    ("lowrank:4", 0.15, 1.0),
])
def test_lossy_codecs_bytes_and_error(spec, max_ratio, max_rel_err):
    tree = _tree()
    flat, _ = tree_to_flat(tree)
    identity_bytes = 4 * flat.size
    codec = make_codec(spec)
    payload, _ = codec.encode(tree, key=KEY)
    assert payload.nbytes <= max_ratio * identity_bytes, spec
    dec, _ = tree_to_flat(codec.decode(payload))
    rel = float(jnp.linalg.norm(dec - flat) / jnp.linalg.norm(flat))
    assert rel <= max_rel_err, (spec, rel)
    # analytic model agrees with the measured bytes to within padding
    analytic = codec.bits_per_param(flat.size) / 8.0 * flat.size
    assert payload.nbytes <= analytic * 1.25 + 64


def test_int4_pack_unpack_roundtrip():
    from repro.comms.quantize import pack_int4, unpack_int4
    codes = jnp.asarray(
        np.random.RandomState(0).randint(-7, 8, (3, 64)), jnp.int8)
    np.testing.assert_array_equal(np.asarray(unpack_int4(pack_int4(codes))),
                                  np.asarray(codes))


def test_lowrank_codec_recovers_lowrank_signal():
    """A genuinely rank-1 flat vector is reconstructed near-exactly."""
    a, b = 64, 64
    u = jax.random.normal(KEY, (a, 1))
    v = jax.random.normal(jax.random.fold_in(KEY, 1), (1, b))
    tree = {"w": (u @ v).reshape(-1)}
    codec = LowRankCodec(rank=2)
    payload, _ = codec.encode(tree, key=KEY)
    dec = codec.decode(payload)["w"]
    flat = tree["w"]
    rel = float(jnp.linalg.norm(dec - flat) / jnp.linalg.norm(flat))
    assert rel < 1e-4


@pytest.mark.parametrize("spec", ["identity", "int8", "topk:0.1",
                                  "int8+ef", "topk:0.1+ef"])
def test_roundtrip_flat_matches_tree_roundtrip(spec):
    """The pre-flattened Payload boundary (used by the vectorized engine's
    batched delta uplink) is payload- and state-equivalent to the
    tree-based roundtrip."""
    tree = _tree()
    flat, tspec = tree_to_flat(tree)
    key = jax.random.fold_in(KEY, 7)
    c1, c2 = make_codec(spec), make_codec(spec)
    p1, s1, dec_tree = c1.roundtrip(tree, None, key=key)
    p2, s2, dec_flat = c2.roundtrip_flat(flat, tspec, None, key=key)
    assert p1.nbytes == p2.nbytes
    for k in p1.arrays:
        np.testing.assert_array_equal(np.asarray(p1.arrays[k]),
                                      np.asarray(p2.arrays[k]))
    np.testing.assert_allclose(np.asarray(tree_to_flat(dec_tree)[0]),
                               np.asarray(dec_flat), rtol=1e-6)
    if s1 is None:
        assert s2 is None
    else:                                 # error-feedback residuals agree
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   rtol=1e-6)


# ------------------------------------------------- stacked-client encode
@pytest.mark.parametrize("spec", ["identity", "int8", "int4", "topk:0.1",
                                  "int8+ef", "int4+ef"])
def test_roundtrip_stacked_byte_identical_to_per_client(spec):
    """The stacked-axis encode (ONE batched kernel dispatch for the
    quantize codecs — the cohort dispatch path) must produce payloads,
    decodes and EF states bit-identical to C per-client
    ``roundtrip_flat`` calls with the same keys."""
    tree = _tree()
    flat, tspec = tree_to_flat(tree)
    flats = jnp.stack([flat, 2.0 * flat, -0.5 * flat])
    keys = [jax.random.fold_in(KEY, 10 + i) for i in range(3)]
    states = [None, jnp.zeros_like(flat), 0.1 * flat] \
        if spec.endswith("+ef") else [None] * 3

    c_stacked, c_per = make_codec(spec), make_codec(spec)
    ps, ns, dec = c_stacked.roundtrip_stacked(flats, tspec, states,
                                              keys=keys)
    assert dec.shape == flats.shape
    for i in range(3):
        p1, s1, d1 = c_per.roundtrip_flat(flats[i], tspec, states[i],
                                          key=keys[i])
        assert ps[i].nbytes == p1.nbytes
        for k in p1.arrays:
            np.testing.assert_array_equal(np.asarray(ps[i].arrays[k]),
                                          np.asarray(p1.arrays[k]))
        np.testing.assert_array_equal(np.asarray(dec[i]), np.asarray(d1))
        if s1 is None:
            assert ns[i] is None
        else:
            np.testing.assert_array_equal(np.asarray(ns[i]),
                                          np.asarray(s1))


def test_encode_stacked_matches_roundtrip_stacked():
    tree = _tree()
    flat, tspec = tree_to_flat(tree)
    flats = jnp.stack([flat, 3.0 * flat])
    keys = [jax.random.fold_in(KEY, 20 + i) for i in range(2)]
    codec = make_codec("int4")
    ps, _ = codec.encode_stacked(flats, tspec, keys=keys)
    ps2, _, _ = codec.roundtrip_stacked(flats, tspec, keys=keys)
    for a, b in zip(ps, ps2):
        for k in a.arrays:
            np.testing.assert_array_equal(np.asarray(a.arrays[k]),
                                          np.asarray(b.arrays[k]))


# ------------------------------------------------------- delta downlink
def test_delta_codec_first_round_full_then_deltas():
    """delta+identity is lossless and the reference chain tracks the
    reconstruction exactly."""
    flat, spec = tree_to_flat(_tree())
    codec = make_codec("delta")
    st = None
    x = flat
    for _ in range(3):
        x = x + 0.01
        p, st, dec = codec.roundtrip_flat(x, spec, st, key=KEY)
        np.testing.assert_allclose(np.asarray(dec), np.asarray(x),
                                   rtol=1e-5, atol=1e-6)
    # from round 2 the wire carries the (tiny) delta, not the weights
    assert float(jnp.abs(p.arrays["values"]).max()) <= 0.011


def test_delta_int8_lower_distortion_same_bytes():
    """Same bits/param as int8, but the quantizer scale tracks the small
    round-to-round delta: distortion collapses from round 2 on."""
    key = jax.random.PRNGKey(3)
    flat, spec = tree_to_flat(_tree(seed=3))
    plain, delta = make_codec("int8"), make_codec("delta+int8")
    assert delta.bits_per_param(flat.size) == plain.bits_per_param(
        flat.size)
    sp, sd = None, None
    x = flat
    errs_p, errs_d = [], []
    for t in range(1, 5):
        x = x + 0.005 * jax.random.normal(jax.random.fold_in(key, t),
                                          x.shape)
        kq = jax.random.fold_in(key, 100 + t)
        pp, sp, dp = plain.roundtrip_flat(x, spec, sp, key=kq)
        pd, sd, dd = delta.roundtrip_flat(x, spec, sd, key=kq)
        assert pp.nbytes == pd.nbytes
        errs_p.append(float(jnp.linalg.norm(dp - x)))
        errs_d.append(float(jnp.linalg.norm(dd - x)))
    # round 1 transmits the full params either way; afterwards the delta
    # codec is at least 10x more accurate at identical wire bytes
    assert all(d < p / 10 for p, d in zip(errs_p[1:], errs_d[1:]))


def test_delta_codec_tree_roundtrip_and_registry():
    from repro.comms import DeltaCodec
    codec = make_codec("delta+int8+ef")
    assert isinstance(codec, DeltaCodec)
    assert isinstance(codec.inner, ErrorFeedback)
    assert codec.name == "delta+int8+ef"
    tree = _tree(scale=0.1)
    p, st, dec = codec.roundtrip(tree, None, key=KEY)
    assert p.nbytes < 0.3 * 4 * tree_to_flat(tree)[0].size
    rel = float(jnp.linalg.norm(tree_to_flat(dec)[0]
                                - tree_to_flat(tree)[0])
                / jnp.linalg.norm(tree_to_flat(tree)[0]))
    assert rel < 0.05
    with pytest.raises(NotImplementedError):
        codec.decode(p)                   # needs the receiver reference


@pytest.mark.slow
def test_engine_delta_downlink_trains():
    tr = _tiny_trainer(downlink_codec="delta+int8")
    h = tr.run(2)
    assert np.isfinite(h[-1]["rewards"]).all()
    d = tr.d_trainable
    # two rounds x C=2 recipients of ~1 byte/param broadcasts
    assert h[-1]["down_bytes"] <= 0.30 * 2 * 2 * 4 * d


# --------------------------------------------------------- error feedback
def test_error_feedback_residual_reinjected():
    """EF conservation: at every step, sum(decoded so far) + residual
    == sum(inputs so far) *exactly* — compression error is deferred,
    never lost — and the relative deferred mass shrinks over time."""
    tree = _tree(scale=0.1)
    flat, _ = tree_to_flat(tree)
    codec = make_codec("topk:0.1+ef")
    state, total = None, jnp.zeros_like(flat)
    rels = {}
    for t in range(1, 31):
        payload, state = codec.encode(tree, state,
                                      key=jax.random.fold_in(KEY, t))
        dec, _ = tree_to_flat(codec.decode(payload))
        total = total + dec
        # conservation identity: total + e_t == t * x (up to f32 roundoff)
        np.testing.assert_allclose(np.asarray(total + state),
                                   np.asarray(t * flat),
                                   rtol=1e-4, atol=1e-5)
        rels[t] = float(jnp.linalg.norm(total - t * flat)
                        / jnp.linalg.norm(t * flat))
    # deferred fraction decays as the residual re-injects (EF property)
    assert rels[30] < rels[5]
    assert rels[30] < 0.5


def test_error_feedback_beats_plain_topk():
    tree = _tree(scale=0.1)
    flat, _ = tree_to_flat(tree)

    def accumulate(spec):
        codec = make_codec(spec)
        state, total = None, jnp.zeros_like(flat)
        for t in range(15):
            p, state = codec.encode(tree, state,
                                    key=jax.random.fold_in(KEY, t))
            dec, _ = tree_to_flat(codec.decode(p))
            total = total + dec
        return float(jnp.linalg.norm(total - 15.0 * flat))

    assert accumulate("topk:0.02+ef") < accumulate("topk:0.02")


# ---------------------------------------------------------------- registry
def test_registry_specs_parse():
    assert make_codec("identity").name == "identity"
    assert make_codec("int8").name == "int8"
    assert make_codec("topk:0.1").frac == 0.1
    assert make_codec("lowrank:8").rank == 8
    ef = make_codec("int4+ef")
    assert isinstance(ef, ErrorFeedback) and ef.inner.bits == 4


def test_registry_rejects_bad_specs():
    with pytest.raises(ValueError):
        make_codec("gzip")
    with pytest.raises(ValueError):
        make_codec("identity+ef")
    with pytest.raises(ValueError):
        make_codec("topk:1.5")


# ------------------------------------------------------- byte accounting
def test_ledger_accepts_payloads_and_trees():
    tree = _tree()
    flat, _ = tree_to_flat(tree)
    ledger = comms.CommsLedger()
    ledger.send_up(tree)                                 # raw pytree
    assert ledger.up_bytes == 4 * flat.size
    payload, _ = make_codec("int8").encode(tree, key=KEY)
    ledger.send_up(payload)                              # encoded payload
    assert ledger.up_bytes == 4 * flat.size + payload.nbytes
    bf16 = {"x": jnp.ones((10,), jnp.bfloat16)}
    ledger.send_down(bf16)                               # itemsize-aware
    assert ledger.down_bytes == 20


# ---------------------------------------------------- engine integration
def _tiny_trainer(**kw):
    from repro.configs import get_config
    from repro.configs.base import FIRMConfig
    from repro.fed.engine import EngineConfig, FederatedTrainer
    cfg = get_config("llama-3.2-1b").reduced(n_layers=2, d_model=64,
                                             vocab=256)
    fc = FIRMConfig(n_objectives=2, n_clients=2, local_steps=1,
                    batch_size=2, beta=0.05)
    ec = EngineConfig(algorithm=kw.pop("algorithm", "firm"), max_new=6,
                      prompt_len=4, **kw)
    return FederatedTrainer(cfg, fc, ec)


def test_engine_config_default_not_shared():
    """The EngineConfig default must be constructed per trainer, not one
    dataclass instance shared by every FederatedTrainer (mutating one
    trainer's ec must not leak into the next)."""
    import inspect
    from repro.fed.engine import EngineConfig, FederatedTrainer
    sig = inspect.signature(FederatedTrainer.__init__)
    assert sig.parameters["ec"].default is None
    tr = _tiny_trainer()
    assert isinstance(tr.ec, EngineConfig)
    tr.ec.algorithm = "mutated"
    assert EngineConfig().algorithm == "firm"


@pytest.mark.slow
def test_engine_int8_uplink_byte_ratio():
    """Acceptance: measured int8 uplink <= ~30% of the identity codec,
    training still healthy."""
    base = _tiny_trainer()
    s0 = base.run(1)[-1]
    tr = _tiny_trainer(uplink_codec="int8+ef")
    s1 = tr.run(1)[-1]
    assert s1["up_bytes"] <= 0.30 * s0["up_bytes"]
    assert s1["down_bytes"] == s0["down_bytes"]          # downlink raw
    assert np.isfinite(s1["rewards"]).all()
    # EF residual allocated per client, client-local
    assert len(tr._uplink_state) == 2
    assert tr._uplink_state[0] is not None


@pytest.mark.slow
def test_engine_coded_downlink_and_fedcmoo_grads():
    tr = _tiny_trainer(uplink_codec="topk:0.1+ef", downlink_codec="int8")
    s = tr.run(1)[-1]
    d = tr.d_trainable
    assert np.isfinite(s["rewards"]).all()
    assert s["down_bytes"] <= 0.30 * 2 * 4 * d       # int8 down, C=2
    assert s["up_bytes"] <= 0.25 * 2 * 4 * d         # topk:0.1 ~ 20% of f32
    fed = _tiny_trainer(algorithm="fedcmoo", uplink_codec="int8")
    sf = fed.run(1)[-1]
    assert np.isfinite(sf["rewards"]).all()
    # raw up would be C*(M*K+1)*4d = 24d: M=2 grad payloads + delta, int8
    assert sf["up_bytes"] <= 0.30 * 24 * d


def test_analytic_codec_round_bytes():
    d, c = 100_000, 8
    raw = comms.firm_round_bytes(d, c)
    coded = comms.firm_round_bytes_codec(d, c, uplink_codec="int8")
    assert coded["down"] == raw["down"]
    assert coded["up"] < 0.3 * raw["up"]
    both = comms.firm_round_bytes_codec(d, c, uplink_codec="int4+ef",
                                        downlink_codec="int8")
    assert both["total"] < 0.3 * raw["total"]
    fed = comms.fedcmoo_round_bytes_codec(d, c, n_objectives=3,
                                          local_steps=2,
                                          uplink_codec="int8")
    fed_raw = comms.fedcmoo_round_bytes(d, c, 3, 2)
    assert fed["up"] < 0.3 * fed_raw["up"]
