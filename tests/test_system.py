"""End-to-end behaviour tests for the FIRM system (paper Alg. 1 semantics).

These exercise the full stack: generation -> synthetic rewards ->
multi-objective PPO -> in-client regularized MGDA -> Adam -> FedAvg.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import FIRMConfig
from repro.core import mgda
from repro.fed.engine import EngineConfig, FederatedTrainer
from repro.models.common import tree_size


def _trainer(algorithm="firm", n_clients=2, beta=0.05, preference=None,
             seed=0):
    cfg = get_config("llama-3.2-1b").reduced(n_layers=2, d_model=64,
                                             vocab=256)
    fc = FIRMConfig(n_objectives=2, n_clients=n_clients, local_steps=1,
                    batch_size=2, beta=beta, preference=preference)
    ec = EngineConfig(algorithm=algorithm, max_new=6, prompt_len=4,
                      seed=seed)
    return FederatedTrainer(cfg, fc, ec)


def test_firm_round_is_wellformed():
    tr = _trainer()
    s = tr.run(2)[-1]
    assert s["rewards"].shape == (2,)
    assert abs(float(np.sum(s["lam_mean"])) - 1.0) < 1e-3
    assert np.isfinite(s["rewards"]).all()


def test_fedavg_synchronises_clients():
    """After a round, the server model is the mean of client adapters."""
    tr = _trainer()
    tr.run(1)
    clients = [s.trainable for s in tr.client_states]
    mean0 = np.mean([np.asarray(jax.tree_util.tree_leaves(c)[0])
                     for c in clients], axis=0)
    server0 = np.asarray(jax.tree_util.tree_leaves(tr.global_trainable)[0])
    np.testing.assert_allclose(server0, mean0, rtol=1e-4, atol=1e-6)


def test_lora_only_communication():
    """The communicated tree is the adapters, a tiny fraction of the model
    (the paper's efficiency premise) — checked at the PAPER's scale via
    eval_shape (no allocation)."""
    from repro.configs import get_config
    from repro.launch import specs as specs_lib
    from repro.models.common import split_trainable
    cfg = get_config("llama-3.2-1b")
    params = specs_lib.param_specs(cfg)
    trainable, _ = split_trainable(params)
    d_adapters = sum(np.prod(l.shape) for l in
                     jax.tree_util.tree_leaves(trainable))
    d_total = cfg.param_count()
    assert d_adapters < 0.01 * d_total  # <1% of the model is communicated


@pytest.mark.slow
def test_preference_changes_lambda():
    """RQ3: preferring objective 0 raises its average MGDA weight."""
    base = _trainer(beta=0.05)
    pref = _trainer(beta=0.05, preference=(4.0, 0.25))
    s0 = base.run(2)
    s1 = pref.run(2)
    lam_base = np.mean([s["lam_mean"][0] for s in s0])
    lam_pref = np.mean([s["lam_mean"][0] for s in s1])
    assert lam_pref > lam_base


def test_identical_gradients_identical_lambda():
    """With identical per-objective gradients across clients, every client
    solves the same QP -> zero disagreement (sanity floor)."""
    g = [jnp.ones((10,)), 2.0 * jnp.ones((10,))]
    G = mgda.gram_matrix(g)
    l1 = mgda.solve(G, 0.05)
    l2 = mgda.solve(G, 0.05)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2))


def test_descent_direction_property():
    """MGDA direction has non-negative inner product with every objective
    gradient (common descent direction, Désidéri 2012)."""
    key = jax.random.PRNGKey(0)
    for seed in range(5):
        k = jax.random.fold_in(key, seed)
        g = jax.random.normal(k, (3, 32))
        G = mgda.gram_matrix(g)
        lam = mgda.solve(G, beta=0.0, trace_normalize=False, iters=2000)
        d = mgda.combine(g, lam)
        inner = np.asarray(g @ d)
        assert inner.min() >= -1e-3


@pytest.mark.slow
def test_three_objectives_end_to_end():
    """A.2.3: M=3 (helpfulness, harmlessness, conciseness) runs."""
    cfg = get_config("llama-3.2-1b").reduced(n_layers=2, d_model=64,
                                             vocab=256)
    fc = FIRMConfig(n_objectives=3, n_clients=2, local_steps=1,
                    batch_size=2, beta=0.05)
    tr = FederatedTrainer(cfg, fc, EngineConfig(max_new=6, prompt_len=4))
    s = tr.run(1)[-1]
    assert s["rewards"].shape == (3,)
    assert abs(float(np.sum(s["lam_mean"])) - 1.0) < 1e-3


@pytest.mark.slow
def test_client_scaling_shapes():
    """Larger client pools (paper A.2.2) run a round cleanly."""
    tr = _trainer(n_clients=4)
    s = tr.run(1)[-1]
    assert s["per_client_lam"].shape == (4, 2)
