"""Fused multi-round engine: R rounds as one jitted program.

Pins the fused round scan (``EngineConfig.fused_rounds``) against the
per-round vectorized engine: bit-identical rewards and aggregates for
identity and int8+ef uplinks, codec-state parity (EF residuals, delta
reconstructions), in-graph participation fold-in equivalence with the
host-side named stream, static byte accounting, and the ScheduledTrainer
``sync`` policy riding the fused path unchanged.

The R=2/C=2 smoke test is the fast-lane compile canary — a fused-program
trace/compile regression fails PRs here instead of on main.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comms import make_codec, tree_to_flat
from repro.configs.base import FIRMConfig, SchedConfig
from repro.fed.engine import EngineConfig, FederatedTrainer
from repro.fed.sched.policies import ScheduledTrainer

from tests.test_fed_vectorized import _cfg


def _trainer(algorithm="firm", *, n_clients=2, local_steps=2, m=2, seed=0,
             fused_rounds=1, **kw):
    fc_kw = {k: kw.pop(k) for k in ("client_preferences", "participation",
                                    "client_local_steps") if k in kw}
    fc = FIRMConfig(n_objectives=m, n_clients=n_clients,
                    local_steps=local_steps, batch_size=2, beta=0.05,
                    **fc_kw)
    ec = EngineConfig(algorithm=algorithm, max_new=6, prompt_len=4,
                      seed=seed, fused_rounds=fused_rounds, **kw)
    return FederatedTrainer(_cfg(), fc, ec)


def _assert_bit_identical(h0, h1, trees=()):
    for a, b in zip(h0, h1):
        np.testing.assert_array_equal(np.asarray(a["rewards"]),
                                      np.asarray(b["rewards"]))
        np.testing.assert_array_equal(
            np.asarray(a["rewards_per_client"]),
            np.asarray(b["rewards_per_client"]))
        np.testing.assert_array_equal(np.asarray(a["per_client_lam"]),
                                      np.asarray(b["per_client_lam"]))
        assert a["participants"] == b["participants"]
        assert a["comm_bytes"] == b["comm_bytes"]
        assert a["up_bytes"] == b["up_bytes"]
        assert a["down_bytes"] == b["down_bytes"]
    for t0, t1 in zip(*trees) if trees else ():
        np.testing.assert_array_equal(np.asarray(t0), np.asarray(t1))


# ---------------------------------------------------------- fast-lane smoke
def test_fused_smoke_compiles_r2_c2():
    """Fast-lane canary: the fused program jits and runs at R=2, C=2 with
    O(1) dispatches per chunk and sane summaries."""
    tr = _trainer(fused_rounds=2)
    hist = tr.run(2)
    assert len(hist) == 2
    assert all(np.isfinite(np.asarray(s["rewards"])).all() for s in hist)
    assert all(s["fused"] == 2 for s in hist)
    assert all(s["cohorts"] == 1 for s in hist)
    # stack + fused scan + unstack across the whole chunk
    assert sum(s["dispatches"] for s in hist) <= 4


def test_fused_equivalence_identity_fast():
    """R=2 fused vs per-round: rewards and aggregates bit-identical."""
    h0 = _trainer().run(2)
    tr1 = _trainer(fused_rounds=2)
    h1 = tr1.run(2)
    tr0 = _trainer()
    tr0.run(2)
    _assert_bit_identical(h0, h1)
    for l0, l1 in zip(jax.tree_util.tree_leaves(tr0.global_trainable),
                      jax.tree_util.tree_leaves(tr1.global_trainable)):
        np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))


# ------------------------------------------------- fused-R vs per-round
@pytest.mark.slow
@pytest.mark.parametrize("alg,uplink", [
    ("firm", "identity"), ("firm", "int8+ef"),
    ("linear", "identity"), ("linear", "int8+ef")])
def test_fused_vs_round_loop_equivalent(alg, uplink):
    """R=3 fused chunk vs three per-round dispatches: rewards are
    bit-identical and the EF residual buffers match exactly after R
    rounds (the host EF path computes its residual in the same jitted
    composition as the fused scan, so even the fms-contracted bits
    agree)."""
    rounds = 3
    tr0 = _trainer(alg, uplink_codec=uplink)
    h0 = tr0.run(rounds)
    tr1 = _trainer(alg, uplink_codec=uplink, fused_rounds=rounds)
    h1 = tr1.run(rounds)
    _assert_bit_identical(h0, h1)
    for l0, l1 in zip(jax.tree_util.tree_leaves(tr0.global_trainable),
                      jax.tree_util.tree_leaves(tr1.global_trainable)):
        np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))
    for s0, s1 in zip(tr0._uplink_state, tr1._uplink_state):
        assert (s0 is None) == (s1 is None)
        if s0 is not None:
            np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


@pytest.mark.slow
def test_fused_delta_downlink_reconstruction_matches():
    """delta+int8 downlink under the fused scan: the reference
    reconstruction chain matches the per-round path to ≤ 1e-6 (the
    reconstruction add is fma-contracted in-graph) and rewards stay
    bit-identical."""
    rounds = 3
    kw = dict(uplink_codec="int8+ef", downlink_codec="delta+int8")
    tr0 = _trainer(**kw)
    h0 = tr0.run(rounds)
    tr1 = _trainer(fused_rounds=rounds, **kw)
    h1 = tr1.run(rounds)
    _assert_bit_identical(h0, h1)
    ref0, _ = tr0._downlink_state
    ref1, _ = tr1._downlink_state
    np.testing.assert_allclose(np.asarray(ref0), np.asarray(ref1),
                               atol=1e-6)
    for s0, s1 in zip(tr0._uplink_state, tr1._uplink_state):
        np.testing.assert_allclose(np.asarray(s0), np.asarray(s1),
                                   atol=1e-6)


def test_fused_partial_participation_matches_named_stream():
    """In-graph participation fold-in ≡ host-side keying on
    (seed, round): the fused chunk draws the same participants as
    ``_sample_participants`` and matches the per-round run."""
    rounds = 3
    tr0 = _trainer(n_clients=4, participation=0.5)
    h0 = tr0.run(rounds)
    tr1 = _trainer(n_clients=4, participation=0.5, fused_rounds=rounds)
    h1 = tr1.run(rounds)
    _assert_bit_identical(h0, h1)
    # a fresh twin reproduces each round's draw from the named stream
    probe = _trainer(n_clients=4, participation=0.5)
    for r, s in enumerate(h1):
        assert s["participants"] == probe._sample_participants(round_idx=r)
        assert len(s["participants"]) == 2


def test_fused_byte_accounting_matches_measured():
    """nbytes_static drives the fused ledger: totals equal the per-round
    path's measured Payload accounting for coded links."""
    rounds = 2
    kw = dict(uplink_codec="int4+ef", downlink_codec="int8")
    h0 = _trainer(**kw).run(rounds)
    h1 = _trainer(fused_rounds=rounds, **kw).run(rounds)
    for a, b in zip(h0, h1):
        assert a["up_bytes"] == b["up_bytes"]
        assert a["down_bytes"] == b["down_bytes"]
        assert a["up_nbytes"] == b["up_nbytes"]
        assert a["down_nbytes"] == b["down_nbytes"]


def test_fused_mode_gating():
    """fedcmoo, the per-client loop, and heterogeneous static configs all
    fall back to per-round execution; run_rounds_fused refuses them."""
    assert _trainer()._fused_mode()[0]
    assert not _trainer("fedcmoo")._fused_mode()[0]
    assert not _trainer(vectorized_clients=False)._fused_mode()[0]
    het = _trainer(n_clients=2, client_local_steps=(1, 2))
    assert not het._fused_mode()[0]
    with pytest.raises(ValueError, match="fused_rounds"):
        het.run_rounds_fused(2)
    # run() falls back silently and still completes the horizon
    tr = _trainer("fedcmoo", fused_rounds=4)
    assert len(tr.run(2)) == 2


def test_fused_uniform_local_steps_override():
    """A uniform client_local_steps override forms one cohort whose K
    differs from fc.local_steps; the fused chunk must honor it."""
    kw = dict(n_clients=2, local_steps=1, client_local_steps=(2, 2))
    h0 = _trainer(**kw).run(2)
    h1 = _trainer(fused_rounds=2, **kw).run(2)
    assert h1[0]["local_steps"] == [2, 2]
    _assert_bit_identical(h0, h1)


def test_fused_chunking_partial_tail():
    """A horizon that is not a multiple of fused_rounds runs the tail as
    a smaller chunk (or single round) and matches the per-round run."""
    h0 = _trainer().run(3)
    h1 = _trainer(fused_rounds=2).run(3)       # chunk of 2 + chunk of 1
    _assert_bit_identical(h0, h1)


# ------------------------------------------------- scheduler integration
def test_sync_policy_rides_fused_rounds():
    """ScheduledTrainer(sync) over a fused trainer: results AND simulated
    timing are unchanged vs the per-round sync policy."""
    rounds = 2
    s0 = ScheduledTrainer(_trainer(uplink_codec="int8+ef"),
                          SchedConfig(policy="sync", profile="bimodal"))
    h0 = s0.run(rounds)
    s1 = ScheduledTrainer(
        _trainer(uplink_codec="int8+ef", fused_rounds=rounds),
        SchedConfig(policy="sync", profile="bimodal"))
    h1 = s1.run(rounds)
    for a, b in zip(h0, h1):
        np.testing.assert_array_equal(np.asarray(a["rewards"]),
                                      np.asarray(b["rewards"]))
        assert a["participants"] == b["participants"]
        assert a["round_duration"] == b["round_duration"]
        assert a["sim_time"] == b["sim_time"]
        assert a["client_seconds"] == b["client_seconds"]
        assert b["policy"] == "sync"


# ------------------------------------------------- traced codec contract
@pytest.mark.parametrize("spec", ["identity", "int8", "int4", "topk:0.05",
                                  "lowrank:4", "int8+ef", "int4+ef",
                                  "topk:0.05+ef", "delta+int8",
                                  "delta+int8+ef"])
def test_nbytes_static_matches_measured(spec):
    """Every codec's static byte model equals the measured Payload bytes
    (the fused engine accounts bytes without materializing payloads)."""
    key = jax.random.PRNGKey(0)
    for d in (1000, 4096, 50000):
        flat = jax.random.normal(key, (d,)) * 0.01
        tspec = tree_to_flat({"w": flat})[1]
        codec = make_codec(spec)
        payload, _, _ = codec.roundtrip_flat(flat, tspec, None, key=key)
        assert codec.nbytes_static(d) == payload.nbytes


@pytest.mark.parametrize("spec", ["identity", "int8", "topk:0.05",
                                  "lowrank:4", "int8+ef", "delta+int8"])
def test_roundtrip_traced_matches_host(spec):
    """The in-graph roundtrip (jitted) decodes bit-identically to the
    host-boundary roundtrip_flat, with codec state threaded as arrays.
    The delta chain's host reconstruction add stays eager (the in-graph
    one is fma-contracted), so it matches to 1e-6 instead of exactly."""
    key = jax.random.PRNGKey(1)
    d = 5000
    flat = jax.random.normal(key, (d,)) * 0.01
    tspec = tree_to_flat({"w": flat})[1]
    c_host, c_traced = make_codec(spec), make_codec(spec)
    host_state, traced_state = None, c_traced.init_state_traced(d, None)
    fn = jax.jit(lambda f, s, k: c_traced.roundtrip_traced(f, s, key=k))
    x = flat
    for t in range(3):
        x = x + 0.005 * jax.random.normal(jax.random.fold_in(key, t),
                                          (d,))
        kq = jax.random.fold_in(key, 100 + t)
        _, host_state, dec_h = c_host.roundtrip_flat(x, tspec, host_state,
                                                     key=kq)
        dec_t, traced_state = fn(x, traced_state, kq)
        if spec.startswith("delta+"):
            np.testing.assert_allclose(np.asarray(dec_h),
                                       np.asarray(dec_t), atol=1e-6)
        else:
            np.testing.assert_array_equal(np.asarray(dec_h),
                                          np.asarray(dec_t))


def test_traced_stacked_matches_host_stacked():
    """roundtrip_traced_stacked (the fused uplink boundary) matches the
    host stacked path bit-for-bit, including EF residual states."""
    key = jax.random.PRNGKey(2)
    c, d = 3, 5000
    flats = jax.random.normal(key, (c, d)) * 0.01
    tspec = tree_to_flat({"w": flats[0]})[1]
    keys = jnp.stack([jax.random.fold_in(key, i) for i in range(c)])
    for spec in ("identity", "int8", "int8+ef"):
        ch, ct = make_codec(spec), make_codec(spec)
        _, ns, dec_h = ch.roundtrip_stacked(flats, tspec, [None] * c,
                                            keys=list(keys))
        ts = ct.init_states_traced(d, [None] * c)
        dec_t, ts2 = jax.jit(
            lambda f, s, k, _ct=ct: _ct.roundtrip_traced_stacked(
                f, s, keys=k))(flats, ts, keys)
        np.testing.assert_array_equal(np.asarray(dec_h), np.asarray(dec_t))
        if spec.endswith("+ef"):
            host_rows = ct.states_to_host(ts2, c)
            for i in range(c):
                np.testing.assert_array_equal(np.asarray(ns[i]),
                                              np.asarray(host_rows[i]))


def test_payload_entropy_estimate():
    """nbytes_entropy: discrete-code payloads compress below their fixed
    layout; f32-only payloads report nbytes unchanged."""
    key = jax.random.PRNGKey(3)
    d = 50000
    # training-delta-like: heavy mass near zero -> skewed code histogram
    flat = jax.random.normal(key, (d,)) * 0.01 * (
        jax.random.uniform(jax.random.fold_in(key, 1), (d,)) < 0.2)
    tspec = tree_to_flat({"w": flat})[1]
    for spec in ("int8", "int4", "topk:0.05"):
        p, _, _ = make_codec(spec).roundtrip_flat(flat, tspec, None,
                                                  key=key)
        assert 0 < p.nbytes_entropy < p.nbytes
    p_id, _, _ = make_codec("identity").roundtrip_flat(flat, tspec, None)
    assert p_id.nbytes_entropy == p_id.nbytes
