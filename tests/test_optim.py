"""Optimizer substrate: Adam semantics, clipping, schedules."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.train import optim


def test_adam_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = optim.adam_init(params)
    target = jnp.asarray([1.0, 2.0])

    def grad_fn(p):
        return {"w": 2.0 * (p["w"] - target)}

    for _ in range(300):
        params, state, _ = optim.adam_update(grad_fn(params), state, params,
                                             lr=0.05)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_adam_matches_reference_step():
    """First Adam step equals -lr * sign-ish update (bias-corrected)."""
    params = {"w": jnp.zeros(3)}
    state = optim.adam_init(params)
    g = {"w": jnp.asarray([1.0, -2.0, 0.5])}
    new, state, gn = optim.adam_update(g, state, params, lr=0.1)
    # after bias correction the first step is exactly -lr * g/|g| elementwise
    want = -0.1 * np.sign([1.0, -2.0, 0.5])
    np.testing.assert_allclose(np.asarray(new["w"]), want, rtol=1e-4)
    np.testing.assert_allclose(float(gn), np.sqrt(1 + 4 + 0.25), rtol=1e-5)


def test_clip_by_global_norm():
    tree = {"a": jnp.ones(4) * 3.0}
    clipped, n = optim.clip_by_global_norm(tree, 1.0)
    np.testing.assert_allclose(float(optim.global_norm(clipped)), 1.0,
                               rtol=1e-5)
    tree2 = {"a": jnp.ones(4) * 0.1}
    same, _ = optim.clip_by_global_norm(tree2, 1.0)
    np.testing.assert_allclose(np.asarray(same["a"]), 0.1)


def test_sgd_update():
    p = {"w": jnp.ones(2)}
    g = {"w": jnp.asarray([1.0, -1.0])}
    new = optim.sgd_update(g, p, lr=0.5)
    np.testing.assert_allclose(np.asarray(new["w"]), [0.5, 1.5])


def test_cosine_lr_shape():
    fn = optim.cosine_lr(1.0, warmup=10, total=100)
    lrs = [float(fn(jnp.asarray(s))) for s in (0, 5, 10, 50, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == 0.5
    assert abs(lrs[2] - 1.0) < 1e-6
    assert lrs[3] < lrs[2]
    assert lrs[4] < 1e-6


def test_adam_skips_none_leaves():
    params = {"a": jnp.ones(2), "b": None}
    state = optim.adam_init(params)
    g = {"a": jnp.ones(2), "b": None}
    new, state, _ = optim.adam_update(g, state, params, lr=0.1)
    assert new["b"] is None
    assert new["a"].shape == (2,)
