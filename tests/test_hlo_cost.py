"""The loop-aware HLO cost walker: scan scaling, dot flops, collectives."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_cost


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def _xla_cost(compiled) -> dict:
    """cost_analysis() returns a dict in older jaxlib, [dict] in newer."""
    ca = compiled.cost_analysis()
    return ca[0] if isinstance(ca, (list, tuple)) else ca


def test_scan_matches_unrolled():
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 128), jnp.float32)

    def scanned(w, x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    def unrolled(w, x):
        for _ in range(10):
            x = jnp.tanh(x @ w)
        return x

    t1 = hlo_cost.analyze(_compile(scanned, w, x).as_text())
    t2 = hlo_cost.analyze(_compile(unrolled, w, x).as_text())
    assert t1["flops"] == pytest.approx(t2["flops"], rel=0.1)
    # XLA's own counter misses the 10x
    xla = _xla_cost(_compile(scanned, w, x))["flops"]
    assert t1["flops"] > 5 * xla


def test_dot_flops_exact():
    a = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 32), jnp.float32)
    t = hlo_cost.analyze(_compile(lambda a, b: a @ b, a, b).as_text())
    want = 2 * 64 * 256 * 32
    assert t["flops"] == pytest.approx(want, rel=0.05)


def test_unrolled_bytes_match_xla():
    a = jax.ShapeDtypeStruct((512, 512), jnp.float32)

    def f(a):
        return jnp.tanh(a @ a) @ a

    c = _compile(f, a)
    t = hlo_cost.analyze(c.as_text())
    xla = _xla_cost(c)["bytes accessed"]
    assert t["bytes"] == pytest.approx(xla, rel=0.5)


def test_nested_scan_multiplies():
    x = jax.ShapeDtypeStruct((16, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def nested(w, x):
        def outer(c, _):
            def inner(ci, _):
                return jnp.tanh(ci @ w), None
            c2, _ = jax.lax.scan(inner, c, None, length=5)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=6)
        return out

    t = hlo_cost.analyze(_compile(nested, w, x).as_text())
    want = 30 * 2 * 16 * 64 * 64     # 6*5 matmuls
    assert t["flops"] == pytest.approx(want, rel=0.3)


def test_dus_counted_in_place():
    buf = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)  # 4 MB
    upd = jax.ShapeDtypeStruct((1, 1024), jnp.float32)     # 4 KB

    def f(buf, upd):
        def body(b, i):
            return jax.lax.dynamic_update_slice(b, upd, (i, 0)), None
        out, _ = jax.lax.scan(body, buf, jnp.arange(100))
        return out

    t = hlo_cost.analyze(_compile(f, buf, upd).as_text())
    # in-place: ~100 * 2 * 4KB, NOT 100 * 8MB
    assert t["bytes"] < 100e6


def test_collective_parse():
    import os
    # (mesh-based collectives need >1 device; parse a synthetic module)
    hlo = """
HloModule m

ENTRY %main (p: f32[1024]) -> f32[1024] {
  %p = f32[1024]{0} parameter(0)
  ROOT %ar = f32[1024]{0} all-reduce(%p), replica_groups={}, to_apply=%add
}
"""
    t = hlo_cost.analyze(hlo)
    assert t["collectives"]["all-reduce"]["count"] == 1
    assert t["collectives"]["all-reduce"]["bytes"] == 4096
