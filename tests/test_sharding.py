"""Partition-spec rules + input_specs shapes (no devices needed —
AbstractMesh carries only axis sizes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config
from repro.configs.base import FIRMConfig
from repro.launch import sharding as sh
from repro.launch import specs as specs_lib

MESH = AbstractMesh((("data", 16), ("model", 16)))


class _FakePath:
    def __init__(self, *names):
        self.names = names


def _path(*names):
    return tuple(type("K", (), {"key": n})() for n in names)


def _leaf(shape):
    return jax.ShapeDtypeStruct(shape, jnp.bfloat16)


def test_embed_vocab_sharded():
    spec = sh.param_spec(_path("embed"), _leaf((128256, 8192)), MESH)
    assert spec == P("model", None)


def test_column_and_row_parallel():
    spec = sh.param_spec(_path("slots", "0", "attn", "wq", "w"),
                         _leaf((16, 4096, 4096)), MESH)
    assert spec == P(None, None, "model")
    spec = sh.param_spec(_path("slots", "0", "attn", "wo", "w"),
                         _leaf((16, 4096, 4096)), MESH)
    assert spec == P(None, "model", None)


def test_lora_replicated():
    spec = sh.param_spec(_path("slots", "0", "attn", "wq", "lora_A"),
                         _leaf((16, 4096, 16)), MESH)
    assert spec == P(None, None, None)


def test_expert_parallel_when_divisible():
    spec = sh.param_spec(_path("slots", "0", "moe", "experts", "w_gate"),
                         _leaf((48, 64, 2048, 1408)), MESH)
    assert spec == P(None, "model", None, None)
    # 8 experts don't divide 16 -> fall back to d_ff tensor parallel
    spec = sh.param_spec(_path("slots", "0", "moe", "experts", "w_gate"),
                         _leaf((32, 8, 4096, 14336)), MESH)
    assert spec == P(None, None, None, "model")
    spec = sh.param_spec(_path("slots", "0", "moe", "experts", "w_down"),
                         _leaf((32, 8, 14336, 4096)), MESH)
    assert spec == P(None, None, "model", None)


def test_divisibility_guard_replicates():
    # 24 heads * 128 = 3072 out dim divides 16; but 100 doesn't
    spec = sh.param_spec(_path("slots", "0", "attn", "wq", "w"),
                         _leaf((4, 512, 100)), MESH)
    assert spec == P(None, None, None)


def test_batch_spec_data_axes():
    assert sh.batch_spec((256, 4096), MESH) == P("data", None)
    assert sh.batch_spec((1, 4096), MESH) == P(None, None)
    multi = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))
    assert sh.batch_spec((64, 128), multi,
                         data_axes=("pod", "data")) == \
        P(("pod", "data"), None)


@pytest.mark.parametrize("arch", list(ASSIGNED_ARCHS))
@pytest.mark.parametrize("shape_name", list(INPUT_SHAPES))
def test_input_specs_build_for_every_pair(arch, shape_name):
    """eval_shape-only construction of every (arch x shape) input pytree."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.subquadratic:
        pytest.skip("full-attention arch skips long_500k (DESIGN §4)")
    spec = specs_lib.input_specs(cfg, shape, FIRMConfig())
    leaves = jax.tree_util.tree_leaves(
        spec, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    assert leaves, (arch, shape_name)
    if spec["kind"] == "train":
        assert spec["batch"].tokens.shape[0] == shape.global_batch
    elif spec["kind"] == "decode":
        assert spec["token"].shape == (shape.global_batch, 1)
        # cache exists for every pattern slot
        assert len(spec["cache"]["slots"]) == len(cfg.pattern)


def test_cache_shardings_rules():
    cfg = get_config("mistral-large-123b")
    cache = specs_lib.cache_specs(cfg, INPUT_SHAPES["decode_32k"])
    shd = sh.cache_shardings(cfg, cache, MESH, batch=128)
    k_sh = shd["slots"]["0"]["k"]
    assert k_sh.spec == P(None, "data", "model", None, None)
    # B=1 long context -> seq sharded over both axes
    cfg2 = get_config("zamba2-1.2b")
    cache2 = specs_lib.cache_specs(cfg2, INPUT_SHAPES["long_500k"])
    shd2 = sh.cache_shardings(cfg2, cache2, MESH, batch=1)
    # find the shared-attn slot kv
    for i, kind in enumerate(cfg2.pattern):
        if kind == "shared_attn":
            assert shd2["slots"][str(i)]["k"].spec == \
                P(None, None, ("data", "model"), None, None)
            break


def test_param_shardings_cover_full_tree():
    cfg = get_config("mixtral-8x7b").reduced()
    params = specs_lib.param_specs(cfg)
    shd = sh.param_shardings(params, MESH)
    n1 = len(jax.tree_util.tree_leaves(params))
    n2 = len(jax.tree_util.tree_leaves(shd))   # NamedSharding is a leaf
    assert n1 == n2
