"""Property-based tests (hypothesis). The whole module skips cleanly when
hypothesis is not installed (see requirements-dev.txt); the deterministic
twins of these invariants live in test_kernels.py / test_mgda.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
hnp = pytest.importorskip("hypothesis.extra.numpy")

from repro.core import mgda  # noqa: E402
from repro.kernels import ref  # noqa: E402
from repro.kernels.gram import gram_pallas  # noqa: E402

settings = hypothesis.settings(max_examples=40, deadline=None)


@hypothesis.settings(max_examples=15, deadline=None)
@hypothesis.given(m=st.integers(1, 8), d=st.integers(1, 3000),
                  seed=st.integers(0, 99))
def test_gram_property(m, d, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (m, d))
    got = np.asarray(gram_pallas(x, interpret=True))
    np.testing.assert_allclose(got, np.asarray(ref.gram(x)),
                               rtol=1e-4, atol=1e-4)
    # PSD + symmetry invariants
    np.testing.assert_allclose(got, got.T, atol=1e-5)
    assert np.linalg.eigvalsh(got).min() > -1e-3


@settings
@hypothesis.given(hnp.arrays(np.float64, (5,),
                             elements=st.floats(-10, 10)))
def test_project_simplex_is_projection(v):
    p = np.asarray(mgda.project_simplex(jnp.asarray(v, jnp.float32)))
    assert abs(p.sum() - 1.0) < 1e-5
    assert (p >= -1e-7).all()
    p2 = np.asarray(mgda.project_simplex(jnp.asarray(p)))
    np.testing.assert_allclose(p, p2, atol=1e-5)


@settings
@hypothesis.given(hnp.arrays(np.float64, (4,), elements=st.floats(-5, 5)),
                  hnp.arrays(np.float64, (4,), elements=st.floats(0, 1)))
def test_project_simplex_is_nearest(v, w):
    """Projection is closer to v than any other simplex point."""
    hypothesis.assume(w.sum() > 0.1)
    v = jnp.asarray(v, jnp.float32)
    p = mgda.project_simplex(v)
    q = jnp.asarray(w / max(w.sum(), 1e-9), jnp.float32)
    assert float(jnp.sum((p - v) ** 2)) <= float(jnp.sum((q - v) ** 2)) + 1e-4
