"""Observability subsystem: record/sink contracts, shared round-summary
builder bit-identity, Perfetto trace shape + schedule reconciliation,
jit-entry instrumentation, plan audits, debug toggles."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import FIRMConfig, SchedConfig
from repro.fed.engine import EngineConfig, FederatedTrainer
from repro.fed.sched.policies import ScheduledTrainer
from repro.obs import (SCHEMA_VERSION, MetricRecord, MetricsPipeline,
                       PlanDriftError, TraceBuilder, audit_run, counter,
                       debug, gauge, jitwatch, make_sink,
                       records_from_round, round_summary, series,
                       span_seconds_by_track, validate_trace)


def _cfg():
    return get_config("llama-3.2-1b").reduced(n_layers=2, d_model=64,
                                              vocab=256)


def _trainer(n_clients=2, local_steps=1, seed=0, **kw):
    fc = FIRMConfig(n_objectives=2, n_clients=n_clients,
                    local_steps=local_steps, batch_size=2, beta=0.05)
    ec = EngineConfig(algorithm=kw.pop("algorithm", "firm"), max_new=6,
                      prompt_len=4, seed=seed, **kw)
    return FederatedTrainer(_cfg(), fc, ec)


def _sched(policy, preset="homogeneous", n_clients=2, **kw):
    sc = SchedConfig(policy=policy, profile=preset, profile_seed=0,
                     overselect=kw.pop("overselect", 1.0),
                     deadline_quantile=kw.pop("deadline_quantile", 0.2),
                     buffer_size=kw.pop("buffer_size", max(n_clients // 2,
                                                           1)))
    return ScheduledTrainer(_trainer(n_clients=n_clients, **kw), sc)


# ------------------------------------------------------------ records
def test_record_kinds_and_schema_stamp():
    r = counter("comm/up_bytes", 1024, 3, policy="sync")
    assert r.kind == "counter" and r.schema == SCHEMA_VERSION
    j = r.to_json()
    assert j == {"schema": SCHEMA_VERSION, "kind": "counter",
                 "name": "comm/up_bytes", "value": 1024, "round": 3,
                 "labels": {"policy": "sync"}}
    assert gauge("x", np.float32(1.5)).to_json()["value"] == 1.5
    assert series("y", jnp.arange(3)).to_json()["value"] == [0, 1, 2]
    with pytest.raises(ValueError):
        MetricRecord("histogram", "x", 1)


def test_make_sink_specs(tmp_path):
    assert make_sink("memory").kind == "memory"
    assert make_sink(f"jsonl:{tmp_path}/a.jsonl").kind == "jsonl"
    assert make_sink(f"csv:{tmp_path}/a.csv").kind == "csv"
    for bad in ("jsonl", "csv:", "parquet:x"):
        with pytest.raises(ValueError):
            make_sink(bad)


def test_jsonl_and_csv_sinks_roundtrip(tmp_path):
    jpath, cpath = tmp_path / "m.jsonl", tmp_path / "m.csv"
    with MetricsPipeline.from_spec(f"jsonl:{jpath},csv:{cpath}") as pipe:
        pipe.emit(gauge("round/kl", 0.25, 0))
        pipe.emit(series("round/rewards", [1.0, 2.0], 0, policy="sync"))
    lines = [json.loads(x) for x in jpath.read_text().splitlines()]
    assert [x["name"] for x in lines] == ["round/kl", "round/rewards"]
    assert all(x["schema"] == SCHEMA_VERSION for x in lines)
    rows = cpath.read_text().splitlines()
    assert rows[0] == "schema,kind,name,round,value,labels"
    assert len(rows) == 3 and "round/rewards" in rows[2]
    # memory sink is always attached alongside the file sinks
    assert pipe.values("round/kl") == [0.25]


def test_pipeline_select_and_values():
    pipe = MetricsPipeline()
    for i in range(3):
        pipe.emit(gauge("round/kl", 0.1 * i, i))
    pipe.emit(gauge("round/param_drift", 9.0, 0))
    assert pipe.values("round/kl") == [0.0, pytest.approx(0.1), pytest.approx(0.2)]
    assert [r.round for r in pipe.select("round/kl")] == [0, 1, 2]


# ------------------------------------- shared round-summary constructor
def _stats():
    return {"rewards": np.array([1.0, 2.0], np.float32),
            "lam_mean": np.array([0.5, 0.5], np.float32),
            "lam_disagreement": np.float32(0.01),
            "param_drift": np.float32(0.002),
            "kl": np.float32(0.3),
            "per_client_lam": np.zeros((2, 2), np.float32),
            "rewards_per_client": np.ones((2, 2), np.float32)}


def test_round_summary_bit_identical_to_legacy_dict():
    """The shared builder must reproduce the engine's legacy hand-built
    summary exactly — same keys, same order, same values."""
    stats = _stats()
    got = round_summary(stats=stats, comm_bytes=300, up_bytes=100,
                        down_bytes=200, participants=[0, 1],
                        dispatches=6, up_nbytes=[50, 50], down_nbytes=200,
                        local_steps=[1, 1], cohorts=1)
    legacy = {
        "rewards": stats["rewards"],
        "lam_mean": stats["lam_mean"],
        "lam_disagreement": float(stats["lam_disagreement"]),
        "param_drift": float(stats["param_drift"]),
        "kl": float(stats["kl"]),
        "comm_bytes": 300,
        "up_bytes": 100,
        "down_bytes": 200,
        "participants": [0, 1],
        "per_client_lam": stats["per_client_lam"],
        "rewards_per_client": stats["rewards_per_client"],
        "dispatches": 6,
        "up_nbytes": [50, 50],
        "down_nbytes": 200,
        "local_steps": [1, 1],
        "cohorts": 1,
    }
    assert list(got) == list(legacy)
    for k in legacy:
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(legacy[k]))
    fused = round_summary(stats=stats, comm_bytes=300, up_bytes=100,
                          down_bytes=200, participants=[0, 1],
                          dispatches=1.5, up_nbytes=[50, 50],
                          down_nbytes=200, local_steps=[1, 1], cohorts=1,
                          fused=4)
    assert list(fused) == list(legacy) + ["fused"] and fused["fused"] == 4


def test_records_from_round_names_and_sched_filter():
    s = round_summary(stats=_stats(), comm_bytes=300, up_bytes=100,
                      down_bytes=200, participants=[0, 1], dispatches=6,
                      up_nbytes=[50, 50], down_nbytes=200,
                      local_steps=[1, 1], cohorts=1)
    names = [r.name for r in records_from_round(s, round=0)]
    assert names == ["round/rewards", "round/lam_mean",
                     "round/lam_disagreement", "round/param_drift",
                     "round/kl", "round/dispatches", "round/cohorts",
                     "round/local_steps", "comm/total_bytes",
                     "comm/up_bytes", "comm/down_bytes", "comm/up_nbytes",
                     "comm/down_nbytes"]
    s.update(policy="sync", sim_time=2.0, round_duration=1.0, dropped=[],
             client_seconds=[1.0, 0.5])
    pipe = MetricsPipeline()
    pipe.emit_schedule(s, round=0)
    got = {r.name for r in pipe.records}
    assert got == {"sched/sim_time", "sched/round_duration",
                   "sched/client_seconds", "sched/dropped"}
    assert all(dict(r.labels)["policy"] == "sync" for r in pipe.records)


# -------------------------------------------------------------- trace
def test_trace_builder_shape_and_track_sums():
    tb = TraceBuilder()
    end = tb.client_span(0, 0.0, [("download", 1.0), ("compute", 2.0),
                                  ("upload", 0.5)], round_idx=0)
    assert end == 3.5
    tb.server_span("round", 0.0, 3.5)
    tb.instant("aggregate", 3.5)
    fid = tb.flow_start("upload", 3.0, client=0)
    tb.flow_end("upload", 3.5, fid)
    tb.counter("in flight", 1.0, {"depth": 1})
    d = tb.to_dict()
    validate_trace(d)
    assert d["displayTimeUnit"] == "ms"
    sums = span_seconds_by_track(d)
    assert sums[(1, 1)] == pytest.approx(3.5)       # client 0 track
    assert sums[(1, 0)] == pytest.approx(3.5)       # server track
    names = {e["name"] for e in d["traceEvents"] if e["ph"] == "M"}
    assert {"process_name", "thread_name"} <= names


def test_validate_trace_rejects_malformed():
    with pytest.raises(ValueError):
        validate_trace({"events": []})
    bad_dur = {"traceEvents": [{"ph": "X", "pid": 1, "tid": 0,
                                "name": "x", "ts": 0}]}
    with pytest.raises(ValueError):
        validate_trace(bad_dur)
    orphan_flow = {"traceEvents": [{"ph": "f", "bp": "e", "pid": 1,
                                    "tid": 0, "name": "u", "ts": 0,
                                    "id": 7}]}
    with pytest.raises(ValueError):
        validate_trace(orphan_flow)
    with pytest.raises(ValueError):
        validate_trace({"traceEvents": [{"ph": "X", "pid": 1, "tid": 0,
                                         "name": "x", "ts": -1, "dur": 1}]})


def test_trace_write_validates_and_roundtrips(tmp_path):
    tb = TraceBuilder()
    tb.client_span(1, 0.0, [("compute", 1.0)])
    path = tmp_path / "t.trace.json"
    tb.write(str(path))
    validate_trace(json.loads(path.read_text()))


# ------------------------------------------------------------ jitwatch
def test_jitwatch_wrap_counts_compiles_and_nests():
    f = jitwatch.wrap("f", jax.jit(lambda x: x + 1))
    f(jnp.zeros(3))                       # inactive: no recorder, no span
    with jitwatch.record() as outer:
        f(jnp.zeros(4))                   # new shape -> compiles
        with jitwatch.record() as inner:
            f(jnp.zeros(4))               # cached -> no compile
        f(jnp.zeros(4))
    assert [s.compiled for s in outer.spans] == [True, False, False]
    assert inner.call_count == 1 and inner.compile_count == 0
    assert outer.compiles_by_name() == {"f": 1}
    assert not jitwatch.active()


# ------------------------------------------------------------- debug
def test_debug_toggles_from_env():
    nans0 = jax.config.jax_debug_nans
    x640 = jax.config.jax_enable_x64
    try:
        applied = debug.configure_from_env(
            {"REPRO_DEBUG_NANS": "on", "REPRO_X64": "0"}, force=True)
        assert applied == {"jax_debug_nans": True, "jax_enable_x64": False}
        assert jax.config.jax_debug_nans is True
        assert debug.configure_from_env({}, force=True) == {}
        with pytest.raises(ValueError):
            debug.configure_from_env({"REPRO_X64": "maybe"}, force=True)
    finally:
        debug.set_debug_nan(nans0)
        debug.set_x64(x640)


# --------------------------------------------- engine -> pipeline wiring
def test_engine_emits_records_per_round(tmp_path):
    jpath = tmp_path / "run.jsonl"
    tr = _trainer(metrics_sink=f"jsonl:{jpath}")
    tr.run(2)
    assert tr.host_transfers == 2
    assert tr.obs.values("round/kl") == [h["kl"] for h in tr.history]
    assert [r.round for r in tr.obs.select("round/rewards")] == [0, 1]
    up = tr.obs.values("comm/up_bytes")
    assert up == [h["up_bytes"] for h in tr.history]
    tr.obs.close()
    lines = [json.loads(x) for x in jpath.read_text().splitlines()]
    assert len(lines) == len(tr.obs.records)
    # pinned summary schema: the shared builder's exact key set
    assert list(tr.history[0]) == [
        "rewards", "lam_mean", "lam_disagreement", "param_drift", "kl",
        "comm_bytes", "up_bytes", "down_bytes", "participants",
        "per_client_lam", "rewards_per_client", "dispatches", "up_nbytes",
        "down_nbytes", "local_steps", "cohorts"]


def test_sync_policy_trace_reconciles_and_is_deterministic():
    def run():
        st = _sched("sync")
        st.run(2)
        return st
    st = run()
    t = st.trace.to_dict()
    validate_trace(t)
    sums = span_seconds_by_track(t)
    # server barrier spans sum to the reported simulated wall-clock
    assert sums[(1, 0)] == pytest.approx(st.history[-1]["sim_time"],
                                         rel=1e-9)
    # each client track sums to its reported per-round seconds
    for c in range(2):
        want = sum(h["client_seconds"][c] for h in st.history)
        assert sums[(1, c + 1)] == pytest.approx(want, abs=1e-5)
    # sched records rode the pipeline without double-emitting round/
    assert len(st.obs.select("sched/sim_time")) == 2
    assert len(st.obs.select("round/kl")) == 2
    assert st.trace.to_dict() == run().trace.to_dict()   # deterministic


# ------------------------------------------------------------- audits
def test_audit_run_per_round_identity():
    report = audit_run(_trainer(), rounds=2).raise_on_drift()
    checks = {c.name: c for c in report.checks}
    assert checks["dispatches_per_round"].predicted == \
        checks["dispatches_per_round"].observed == 6
    assert checks["up_bytes_per_round"].enforced
    assert checks["host_transfers_per_round"].observed == 1.0
    assert report.jit_calls > 0 and report.to_json()["ok"]


def test_audit_rejects_partial_fused_chunk():
    tr = _trainer(fused_rounds=2)
    with pytest.raises(ValueError):
        audit_run(tr, rounds=3)


def test_plan_drift_error_raises():
    report = audit_run(_trainer(), rounds=2)
    object.__setattr__(report.checks[0], "predicted", 999.0)
    assert not report.ok
    with pytest.raises(PlanDriftError):
        report.raise_on_drift()


@pytest.mark.slow
@pytest.mark.parametrize("codec", ["identity", "int8+ef"])
@pytest.mark.parametrize("fused", [1, 2])
def test_audit_matrix_plan_matches_observed(codec, fused):
    """Acceptance: predicted == observed dispatches and wire bytes for
    firm x {identity, int8+ef} on both executors."""
    tr = _trainer(uplink_codec=codec, fused_rounds=fused)
    report = audit_run(tr).raise_on_drift()
    assert report.executor == ("fused" if fused > 1 else "vectorized")
    checks = {c.name: c for c in report.checks}
    assert checks["recompiles_after_warmup"].observed == 0
    assert checks["host_transfers_per_round"].observed == 1.0 / fused


# ------------------------------------------------- fused-path overhead
@pytest.mark.slow
def test_fused_instrumentation_adds_no_compiles_or_transfers():
    """A warm fused chunk under full instrumentation stays O(1): three
    dispatches, one host transfer, zero new compilations — telemetry is
    derived from the stacked scan outputs, not extra syncs."""
    tr = _trainer(fused_rounds=2)
    tr.run(2)                                     # compile/warmup chunk
    d0, h0, n0 = tr.jit_dispatches, tr.host_transfers, len(tr.obs.records)
    with jitwatch.record() as log:
        tr.run(2)
    assert log.compile_count == 0
    assert tr.jit_dispatches - d0 == 3            # stack + fused + unstack
    assert tr.host_transfers - h0 == 1
    # and the chunk still emitted one full record set per round
    per_round = [r for r in tr.obs.records[n0:] if r.name == "round/kl"]
    assert [r.round for r in per_round] == [2, 3]


@pytest.mark.slow
def test_fused_records_match_per_round_records():
    """The fused executor's derived per-round records match the per-round
    executor's: rewards and byte ledgers exactly (the engines pin them
    bit-identical), scalar summary stats to float tolerance (their
    reduction order differs inside the round-level scan)."""
    a, b = _trainer(), _trainer(fused_rounds=2)
    a.run(2), b.run(2)
    for name in ("comm/up_bytes", "comm/down_bytes", "comm/total_bytes"):
        assert a.obs.values(name) == b.obs.values(name), name
    for name in ("round/kl", "round/param_drift"):
        np.testing.assert_allclose(a.obs.values(name), b.obs.values(name),
                                   rtol=1e-5, err_msg=name)
    ra = [np.asarray(r.value) for r in a.obs.select("round/rewards")]
    rb = [np.asarray(r.value) for r in b.obs.select("round/rewards")]
    for x, y in zip(ra, rb):
        np.testing.assert_array_equal(x, y)


# ------------------------------------------- heterogeneity trace (slow)
@pytest.mark.slow
@pytest.mark.parametrize("policy", ["deadline", "fedbuff"])
def test_bimodal_policy_traces_validate_and_reconcile(policy):
    st = _sched(policy, preset="bimodal", n_clients=8)
    st.run(2)
    t = st.trace.to_dict()
    validate_trace(t)
    sums = span_seconds_by_track(t)
    assert sums[(1, 0)] == pytest.approx(st.history[-1]["sim_time"],
                                         rel=1e-9)
    if policy == "deadline":
        # dropped clients still render their (cut-short) work
        dropped = st.history[0]["dropped"]
        assert dropped and all((1, c + 1) in sums for c in dropped)
        assert any(e["name"] == "deadline missed"
                   for e in t["traceEvents"] if e["ph"] == "i")
    else:
        # uploads connect to their consuming aggregation via flows and
        # the queue depth renders as a counter track
        phs = {e["ph"] for e in t["traceEvents"]}
        assert {"s", "f", "C"} <= phs
        assert st.obs.values("sched/staleness_max") == [
            max(h["staleness"]) for h in st.history]


@pytest.mark.slow
def test_export_trace_writes_valid_file(tmp_path):
    st = _sched("sync")
    st.run(1)
    path = tmp_path / "sched.trace.json"
    st.export_trace(str(path))
    validate_trace(json.loads(path.read_text()))


# ------------------------------------------------- benchmark plumbing
def test_bench_cell_sink_spec_and_trace_path(tmp_path):
    from benchmarks import common
    old = dict(common.OPTIONS)
    try:
        common.OPTIONS.update(trace_out=str(tmp_path), metrics_sink=None)
        assert common.cell_sink_spec("cell") is None
        assert common.trace_path("cell") == str(tmp_path /
                                                "cell.trace.json")
        common.OPTIONS["metrics_sink"] = "jsonl:out.jsonl,memory"
        assert common.cell_sink_spec("c1") == "jsonl:out.c1.jsonl,memory"
        common.OPTIONS["trace_out"] = None
        assert common.trace_path("cell") is None
    finally:
        common.OPTIONS.update(old)
