"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes and dtypes.  (Hypothesis property sweeps live in
test_properties_hypothesis.py so this module collects without it.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gram import gram_pallas
from repro.kernels.rmsnorm import rmsnorm as rmsnorm_pallas


# ------------------------------------------------------------------ gram
@pytest.mark.parametrize("m", [1, 2, 3, 5, 8])
@pytest.mark.parametrize("d", [100, 8192, 10000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_sweep(m, d, dtype):
    key = jax.random.PRNGKey(m * 1000 + d)
    x = (jax.random.normal(key, (m, d)) * 0.3).astype(dtype)
    got = gram_pallas(x, interpret=True)
    want = ref.gram(x)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("seed", [0, 7, 42])
def test_gram_invariants(seed):
    """Deterministic twin of the hypothesis sweep: symmetry + PSD."""
    rng = np.random.RandomState(seed)
    m, d = int(rng.randint(1, 9)), int(rng.randint(1, 3000))
    x = jax.random.normal(jax.random.PRNGKey(seed), (m, d))
    got = np.asarray(gram_pallas(x, interpret=True))
    np.testing.assert_allclose(got, np.asarray(ref.gram(x)),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(got, got.T, atol=1e-5)
    assert np.linalg.eigvalsh(got).min() > -1e-3


# -------------------------------------------------------------- attention
@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(hq, hkv, causal, dtype):
    key = jax.random.PRNGKey(0)
    b, s, dh = 2, 128, 64
    q = jax.random.normal(key, (b, s, hq, dh)).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1),
                          (b, s, hkv, dh)).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2),
                          (b, s, hkv, dh)).astype(dtype)
    got = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    want = ref.flash_attention(q, k, v, causal=causal)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [16, 64, 128])
def test_flash_attention_sliding_window(window):
    key = jax.random.PRNGKey(3)
    b, s, h, dh = 1, 256, 2, 32
    q = jax.random.normal(key, (b, s, h, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, dh))
    got = flash_attention(q, k, v, causal=True, sliding_window=window,
                          block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention(q, k, v, causal=True, sliding_window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_matches_model_xla_twin():
    """The XLA chunked_attention used by the models == the Pallas kernel."""
    from repro.models.attention import chunked_attention
    key = jax.random.PRNGKey(9)
    b, s, hq, hkv, dh = 2, 128, 4, 2, 32
    q = jax.random.normal(key, (b, s, hq, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, dh))
    a = chunked_attention(q, k, v, causal=True, block=64)
    p = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                        interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(p),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------- rmsnorm
@pytest.mark.parametrize("shape", [(7, 64), (2, 33, 256), (1, 1, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, shape).astype(dtype)
    g = jax.random.normal(jax.random.fold_in(key, 1), shape[-1:]).astype(dtype)
    got = rmsnorm_pallas(x, g, interpret=True, block_rows=4)
    want = ref.rmsnorm(x, g)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_rmsnorm_matches_model_impl():
    from repro.models.common import rms_norm
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (4, 128))
    g = jnp.ones((128,))
    np.testing.assert_allclose(
        np.asarray(rmsnorm_pallas(x, g, interpret=True)),
        np.asarray(rms_norm({"g": g}, x)), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- dispatch
def test_ops_dispatch_gram_pytrees():
    key = jax.random.PRNGKey(2)
    grads = [{"a": jax.random.normal(jax.random.fold_in(key, j), (40,))}
             for j in range(2)]
    got = ops.gram_from_pytrees(grads)
    from repro.core.mgda import gram_matrix
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(gram_matrix(grads)),
                               rtol=1e-4, atol=1e-5)


# -------------------------------------------------------------------- ssd
@pytest.mark.parametrize("chunk", [16, 64])
@pytest.mark.parametrize("shape", [(2, 64, 16, 8), (1, 128, 64, 64),
                                   (4, 32, 8, 16)])
def test_ssd_scan_sweep(chunk, shape):
    from repro.kernels.ssd import ssd_scan
    bh, s, hd, ds = shape
    key = jax.random.PRNGKey(bh * 100 + s)
    x = 0.5 * jax.random.normal(key, (bh, s, hd))
    b = 0.3 * jax.random.normal(jax.random.fold_in(key, 1), (bh, s, ds))
    c = 0.3 * jax.random.normal(jax.random.fold_in(key, 2), (bh, s, ds))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 3),
                                           (bh, s)))
    da = -0.1 * jax.nn.softplus(jax.random.normal(
        jax.random.fold_in(key, 4), (bh, s)))
    got = ssd_scan(x, b, c, dt, da, chunk=chunk, interpret=True)
    want = ref.ssd_scan(x, b, c, dt, da)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-3, atol=3e-3)


def test_ssd_kernel_matches_model_ssm_block():
    """The Pallas SSD and the model's chunked SSD agree with the exact
    per-token recurrence (transitively with each other)."""
    from repro.configs import get_config
    from repro.models import ssm
    cfg = get_config("zamba2-1.2b").reduced(n_layers=2, d_model=64,
                                            vocab=64)
    p = ssm.init_mamba2(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model))
    y_seq = ssm.mamba2_seq(p, cfg, x)
    cache = ssm.init_mamba2_cache(cfg, 1)
    ys = []
    for t in range(32):
        y_t, cache = ssm.mamba2_decode(p, cfg, x[:, t:t + 1], cache)
        ys.append(y_t)
    np.testing.assert_allclose(np.asarray(y_seq),
                               np.asarray(jnp.concatenate(ys, 1)),
                               rtol=3e-3, atol=3e-3)
