"""Per-architecture smoke tests (deliverable f) + block-level numerics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import list_archs, get_config
from repro.models import ssm, transformer as T, xlstm
from repro.models.attention import chunked_attention, decode_attention
from repro.models.moe import moe_ffn, init_moe
from repro.kernels import ref

KEY = jax.random.PRNGKey(0)

# Architectures whose un-jitted smoke step dominates suite wall-time on
# CPU; they run in the full tier-1 but not in `pytest -m "not slow"`.
SLOW_ARCHS = {"zamba2-1.2b", "llama-3.2-vision-90b", "xlstm-125m",
              "whisper-large-v3", "moonshot-v1-16b-a3b",
              "phi4-mini-3.8b", "mixtral-8x7b"}


def _arch_params(archs):
    return [pytest.param(a, marks=pytest.mark.slow) if a in SLOW_ARCHS
            else a for a in archs]


def make_aux(cfg, b, s):
    aux = {}
    if cfg.family == "vlm":
        aux["vision"] = jnp.ones((b, cfg.n_vision_tokens, cfg.d_model),
                                 jnp.bfloat16)
    if cfg.is_encoder_decoder:
        aux["frames"] = jnp.ones((b, s, cfg.d_model), jnp.bfloat16)
    return aux


@pytest.mark.parametrize("arch", _arch_params(list_archs()))
def test_arch_smoke_forward_and_train_step(arch):
    """Reduced variant: one forward + one FIRM train step, shapes + no NaN."""
    cfg = get_config(arch).reduced(n_layers=2, d_model=128, vocab=256)
    params = T.init_params(cfg, KEY)
    b, s = 2, 32
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    aux = make_aux(cfg, b, s)
    out = T.forward_seq(cfg, params, tokens, aux)
    assert out["logits"].shape == (b, s, cfg.vocab)
    assert not np.isnan(np.asarray(out["logits"], np.float32)).any()

    # one full FIRM local step (PPO x2 -> MGDA -> Adam) on the same arch
    from repro.configs.base import FIRMConfig
    from repro.models.common import split_trainable
    from repro.rlhf import local as local_lib, ppo
    fc = FIRMConfig(batch_size=b)
    trainable, frozen = split_trainable(params)
    state = local_lib.init_client_state(trainable, 2, cfg.d_model)
    mask = jnp.concatenate([jnp.zeros((b, s // 2)), jnp.ones((b, s // 2))],
                           axis=1).astype(jnp.float32)
    lp = -jnp.ones((b, s), jnp.float32)
    batch = ppo.PPOBatch(tokens, mask, lp, lp,
                         jax.random.uniform(KEY, (b, 2)))
    new_state, metrics = local_lib.firm_local_step(cfg, fc, state, frozen,
                                                   batch, aux or None)
    assert metrics["lam"].shape == (2,)
    assert not np.isnan(float(metrics["losses"].sum()))
    assert abs(float(metrics["lam"].sum()) - 1.0) < 1e-4


@pytest.mark.parametrize("arch", _arch_params(
    ["llama-3.2-1b", "mixtral-8x7b", "zamba2-1.2b", "xlstm-125m",
     "whisper-large-v3", "llama-3.2-vision-90b"]))
def test_prefill_decode_consistency(arch):
    """decode logits after prefill(S) match the teacher-forced forward at
    position S (same params, same tokens)."""
    cfg = get_config(arch).reduced(n_layers=2, d_model=64, vocab=128)
    params = T.init_params(cfg, KEY, dtype=jnp.float32)
    b, s = 2, 16
    tokens = jax.random.randint(KEY, (b, s + 1), 0, cfg.vocab)
    aux = make_aux(cfg, b, s + 1)
    full = T.forward_seq(cfg, params, tokens, aux)
    _, cache = T.prefill(cfg, params, tokens[:, :s], aux,
                         cache_len=s + 4, cache_dtype=jnp.float32)
    logits, _ = T.decode_step(cfg, params, cache, tokens[:, s:s + 1])
    want = np.asarray(full["logits"][:, s], np.float32)
    got = np.asarray(logits, np.float32)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_chunked_attention_matches_oracle():
    b, s, hq, hkv, dh = 2, 96, 4, 2, 16
    q = jax.random.normal(KEY, (b, s, hq, dh))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, hkv, dh))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, hkv, dh))
    for block in (16, 32, 96, 200):
        got = chunked_attention(q, k, v, causal=True, block=block)
        want = ref.flash_attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


def test_swa_ring_cache_decode():
    """Decode with a ring-buffer SWA cache == full-cache attention with a
    sliding-window mask."""
    b, hq, hkv, dh, w = 1, 2, 2, 8, 8
    total = 20
    k_full = jax.random.normal(KEY, (b, total, hkv, dh))
    v_full = jax.random.normal(jax.random.fold_in(KEY, 1),
                               (b, total, hkv, dh))
    q = jax.random.normal(jax.random.fold_in(KEY, 2), (b, 1, hq, dh))
    pos = 15  # current position
    # ring cache of size w holding positions (pos-w, pos]
    ring_k = jnp.zeros((b, w, hkv, dh))
    ring_v = jnp.zeros((b, w, hkv, dh))
    for p in range(pos + 1):
        ring_k = ring_k.at[:, p % w].set(k_full[:, p])
        ring_v = ring_v.at[:, p % w].set(v_full[:, p])
    cache_positions = jnp.asarray([pos - ((pos - j) % w) for j in range(w)])
    got = decode_attention(q, ring_k, ring_v, jnp.asarray(pos),
                           sliding_window=w, cache_positions=cache_positions)
    want = decode_attention(q, k_full[:, :pos + 1], v_full[:, :pos + 1],
                            jnp.asarray(pos), sliding_window=w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_moe_topk1_matches_dense_expert():
    """With top_k=1 and ample capacity, each token's output equals its
    selected expert's FFN output."""
    import dataclasses
    cfg = get_config("mixtral-8x7b").reduced(n_layers=2, d_model=32,
                                             vocab=64)
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, top_k=1, capacity_factor=8.0))
    p = init_moe(KEY, cfg, dtype=jnp.float32)
    x = jax.random.normal(KEY, (2, 8, cfg.d_model))
    y, aux = moe_ffn(p, cfg, x)
    # manual: route each token and apply its expert
    xf = x.reshape(-1, cfg.d_model)
    logits = xf @ p["router"]["w"]
    eid = jnp.argmax(logits, -1)
    w = p["experts"]
    for t in range(xf.shape[0]):
        e = int(eid[t])
        g = jax.nn.silu(xf[t] @ w["w_gate"][e]) * (xf[t] @ w["w_up"][e])
        want = g @ w["w_down"][e]
        np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model)[t]),
                                   np.asarray(want), rtol=1e-3, atol=1e-3)
    assert float(aux) >= 0.0


def test_moe_grad_flows_to_router_and_experts():
    cfg = get_config("mixtral-8x7b").reduced(n_layers=2, d_model=32,
                                             vocab=64)
    p = init_moe(KEY, cfg, dtype=jnp.float32)
    x = jax.random.normal(KEY, (1, 8, cfg.d_model))

    def loss(p):
        y, aux = moe_ffn(p, cfg, x)
        return (y ** 2).sum() + aux

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]["w"]).sum()) > 0
    assert float(jnp.abs(g["experts"]["w_gate"]).sum()) > 0


@pytest.mark.slow
def test_mamba2_chunked_matches_stepwise():
    """The chunked SSD forward == exact per-token recurrence (decode)."""
    cfg = get_config("zamba2-1.2b").reduced(n_layers=2, d_model=64,
                                            vocab=64)
    p = ssm.init_mamba2(KEY, cfg, dtype=jnp.float32)
    b, s = 1, 40
    x = 0.5 * jax.random.normal(KEY, (b, s, cfg.d_model))
    y_seq, state = ssm.mamba2_seq(p, cfg, x, return_state=True)
    cache = ssm.init_mamba2_cache(cfg, b)
    ys = []
    for t in range(s):
        y_t, cache = ssm.mamba2_decode(p, cfg, x[:, t:t + 1], cache)
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_step),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(state["state"]),
                               np.asarray(cache["state"]),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("kind", ["mlstm", "slstm"])
def test_xlstm_seq_matches_stepwise(kind):
    cfg = get_config("xlstm-125m").reduced(n_layers=3, d_model=64, vocab=64)
    init = {"mlstm": xlstm.init_mlstm, "slstm": xlstm.init_slstm}[kind]
    seqf = {"mlstm": xlstm.mlstm_seq, "slstm": xlstm.slstm_seq}[kind]
    decf = {"mlstm": xlstm.mlstm_decode, "slstm": xlstm.slstm_decode}[kind]
    cachef = {"mlstm": xlstm.init_mlstm_cache,
              "slstm": xlstm.init_slstm_cache}[kind]
    p = init(KEY, cfg, dtype=jnp.float32)
    b, s = 1, 12
    x = 0.5 * jax.random.normal(KEY, (b, s, cfg.d_model))
    y_seq = seqf(p, cfg, x)
    cache = cachef(cfg, b)
    ys = []
    for t in range(s):
        y_t, cache = decf(p, cfg, x[:, t:t + 1], cache)
        ys.append(y_t)
    np.testing.assert_allclose(np.asarray(y_seq),
                               np.asarray(jnp.concatenate(ys, 1)),
                               rtol=1e-4, atol=1e-4)


def test_lora_zero_init_is_identity():
    """Fresh LoRA adapters leave the forward unchanged (B=0 init)."""
    from repro.models.common import linear, init_linear
    p = init_linear(KEY, 16, 24, lora_rank=4, dtype=jnp.float32)
    x = jax.random.normal(KEY, (3, 16))
    np.testing.assert_allclose(np.asarray(linear(p, x)),
                               np.asarray(x @ p["w"]), rtol=1e-6)


def test_split_trainable_roundtrip():
    from repro.models.common import merge_trainable, split_trainable
    cfg = get_config("llama-3.2-1b").reduced()
    params = T.init_params(cfg, KEY)
    tr, fz = split_trainable(params)
    # only lora leaves trainable (stacked over periods -> 8 leaves)
    n_tr = len(jax.tree_util.tree_leaves(tr))
    assert n_tr == 2 * 4  # (A+B) x 4 projections, stacked over layers
    merged = merge_trainable(tr, fz)
    for a, b in zip(jax.tree_util.tree_leaves(merged),
                    jax.tree_util.tree_leaves(params)):
        assert a.shape == b.shape
    # xlstm has no adapters -> full-param mode
    cfg2 = get_config("xlstm-125m").reduced()
    p2 = T.init_params(cfg2, KEY)
    tr2, _ = split_trainable(p2)
    assert len(jax.tree_util.tree_leaves(tr2)) == \
        len(jax.tree_util.tree_leaves(p2))


def test_param_count_close_to_actual():
    for arch in ("llama-3.2-1b", "mixtral-8x7b", "zamba2-1.2b"):
        cfg = get_config(arch).reduced(n_layers=4, d_model=128, vocab=256)
        params = T.init_params(cfg, KEY)
        actual = sum(x.size for x in jax.tree_util.tree_leaves(params)
                     if x.dtype != jnp.float32)  # exclude lora/f32 extras
        est = cfg.param_count()
        assert 0.5 * actual < est < 2.0 * actual, (arch, est, actual)
