"""Scheduler subsystem: sync bit-identity, event-clock determinism,
staleness weights / β scaling, fedbuff & deadline equivalence anchors,
cohort-vectorized dispatch, named participation PRNG stream."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import FIRMConfig, SchedConfig
from repro.core import fedavg, firm
from repro.fed.engine import EngineConfig, FederatedTrainer
from repro.fed.sched import (EventQueue, SimClock, build_cohorts,
                             sample_profiles)
from repro.fed.sched.policies import ScheduledTrainer


def _cfg():
    return get_config("llama-3.2-1b").reduced(n_layers=2, d_model=64,
                                              vocab=256)


def _trainer(n_clients=2, local_steps=1, seed=0, **kw):
    fc_kw = {k: kw.pop(k) for k in ("client_local_steps", "participation",
                                    "client_preferences") if k in kw}
    fc = FIRMConfig(n_objectives=2, n_clients=n_clients,
                    local_steps=local_steps, batch_size=2, beta=0.05,
                    **fc_kw)
    ec = EngineConfig(algorithm=kw.pop("algorithm", "firm"), max_new=6,
                      prompt_len=4, seed=seed, **kw)
    return FederatedTrainer(_cfg(), fc, ec)


def _assert_trees_equal(t0, t1):
    for a, b in zip(jax.tree_util.tree_leaves(t0),
                    jax.tree_util.tree_leaves(t1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------- clock / queue
def test_event_queue_deterministic_tie_break():
    q = EventQueue()
    q.push(1.0, "b")
    q.push(0.5, "a")
    q.push(1.0, "c")                      # same time as "b": seq decides
    assert [q.pop().item for _ in range(3)] == ["a", "b", "c"]


def test_sim_clock_monotone():
    clk = SimClock()
    clk.advance_to(2.0)
    clk.advance_by(1.5)
    assert clk.now == 3.5
    with pytest.raises(ValueError):
        clk.advance_to(1.0)
    with pytest.raises(ValueError):
        clk.advance_by(-1.0)


# ---------------------------------------------------------- profiles
def test_profiles_deterministic_and_presets():
    for preset in ("homogeneous", "uniform", "lognormal", "bimodal"):
        p0 = sample_profiles(8, preset, seed=3)
        p1 = sample_profiles(8, preset, seed=3)
        assert p0 == p1
        assert all(p.tokens_per_sec > 0 and p.up_bytes_per_sec > 0
                   for p in p0)
    assert len(set(sample_profiles(16, "bimodal", seed=0))) == 2
    with pytest.raises(ValueError):
        sample_profiles(4, "warp-speed")


# ------------------------------------------------ staleness primitives
def test_staleness_weights_sum_to_one_and_discount():
    w = np.asarray(fedavg.staleness_weights([0, 1, 5], pow=0.5))
    np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-6)
    assert w[0] > w[1] > w[2]
    # zero staleness -> exactly uniform (sync FedAvg weights)
    w0 = np.asarray(fedavg.staleness_weights([0, 0, 0, 0]))
    np.testing.assert_allclose(w0, 0.25, rtol=1e-6)


def test_staleness_beta_hook():
    assert firm.staleness_beta(0.05, 0, gain=1.0) == pytest.approx(0.05)
    assert firm.staleness_beta(0.05, 3, gain=1.0) == pytest.approx(0.2)
    assert firm.staleness_beta(0.05, 100, gain=1.0, cap=4.0) == \
        pytest.approx(0.2)
    assert firm.staleness_beta(0.05, 7, gain=0.0) == pytest.approx(0.05)


# ------------------------------------------------- named PRNG stream
def test_participation_stream_independent_of_main_rng():
    """Participation draws must not move when other components consume
    PRNG keys — deadline over-selection reproduces sync's draw."""
    tr = _trainer(n_clients=8, participation=0.5)
    p0 = tr._sample_participants()
    for _ in range(7):
        tr._next_key()                    # perturb the main stream
    assert tr._sample_participants() == p0
    # a fresh trainer with the same seed agrees round by round
    tr2 = _trainer(n_clients=8, participation=0.5)
    assert tr2._sample_participants(round_idx=0) == p0
    # over-selection reads the same named stream, deterministically
    assert tr2._sample_participants(n=6) == tr._sample_participants(n=6)


# ------------------------------------------------- sync bit-identity
def test_sync_policy_bit_identical_to_engine():
    s_eng = _trainer().run(2)
    st = ScheduledTrainer(_trainer(),
                          SchedConfig(policy="sync", profile="bimodal"))
    s_sched = st.run(2)
    for a, b in zip(s_eng, s_sched):
        np.testing.assert_array_equal(np.asarray(a["rewards"]),
                                      np.asarray(b["rewards"]))
        np.testing.assert_array_equal(np.asarray(a["per_client_lam"]),
                                      np.asarray(b["per_client_lam"]))
        assert a["comm_bytes"] == b["comm_bytes"]
    # timing annotations exist and advance monotonically
    assert s_sched[0]["round_duration"] > 0
    assert s_sched[1]["sim_time"] > s_sched[0]["sim_time"]


# ------------------------------------------------- fedbuff anchors
@pytest.mark.parametrize("downlink", [
    "identity", pytest.param("int8", marks=pytest.mark.slow)])
def test_fedbuff_zero_staleness_equals_sync_fedavg(downlink):
    """Homogeneous profiles + buffer B = C: every arrival has staleness
    0, weights are uniform, and the whole run — rewards, per-client
    rewards, comm bytes, aggregated params — is bit-identical to the
    sync barrier.  Holds under a lossy downlink too: aggregation
    anchors on the decoded broadcast, exactly like the engine round."""
    sync = ScheduledTrainer(_trainer(downlink_codec=downlink),
                            SchedConfig(policy="sync"))
    hs = sync.run(2)
    fb = ScheduledTrainer(_trainer(downlink_codec=downlink),
                          SchedConfig(policy="fedbuff", buffer_size=2))
    hf = fb.run(2)
    for a, b in zip(hs, hf):
        np.testing.assert_array_equal(
            np.asarray(a["rewards_per_client"]),
            np.asarray(b["rewards_per_client"]))
        assert b["staleness"] == [0, 0]
        np.testing.assert_allclose(b["staleness_weights"], 0.5, rtol=1e-9)
        assert a["comm_bytes"] == b["comm_bytes"]
    _assert_trees_equal(sync.trainer.global_trainable,
                        fb.trainer.global_trainable)


def test_fedbuff_event_clock_deterministic():
    """Same seed, same config -> identical schedules, staleness and
    rewards (the event queue's (time, seq) order is total)."""
    def run():
        st = ScheduledTrainer(
            _trainer(n_clients=4),
            SchedConfig(policy="fedbuff", buffer_size=2,
                        profile="bimodal", staleness_beta_gain=1.0))
        return st.run(3)
    h0, h1 = run(), run()
    for a, b in zip(h0, h1):
        assert a["sim_time"] == b["sim_time"]
        assert a["participants"] == b["participants"]
        assert a["staleness"] == b["staleness"]
        np.testing.assert_array_equal(np.asarray(a["rewards"]),
                                      np.asarray(b["rewards"]))


@pytest.mark.slow
def test_fedbuff_bimodal_staleness_appears_and_trains():
    """Under edge-vs-datacenter heterogeneity the buffer fills from the
    fast minority while stragglers age: staleness > 0 must appear, the
    staleness-β coupling must kick in, and training stays healthy."""
    st = ScheduledTrainer(
        _trainer(n_clients=4),
        SchedConfig(policy="fedbuff", buffer_size=2, profile="bimodal",
                    staleness_beta_gain=1.0, staleness_bucket_max=2))
    h = st.run(4)
    assert max(max(e["staleness"]) for e in h) >= 1
    assert all(np.isfinite(np.asarray(e["rewards"])).all() for e in h)
    # weights of a stale arrival are strictly discounted
    for e in h:
        if max(e["staleness"]) > min(e["staleness"]):
            ws = dict(zip(e["staleness"], e["staleness_weights"]))
            assert ws[max(ws)] < ws[min(ws)]


# ------------------------------------------------- deadline anchors
def test_deadline_infinite_equals_sync():
    sync = ScheduledTrainer(_trainer(n_clients=4, participation=0.5),
                            SchedConfig(policy="sync"))
    hs = sync.run(2)
    dl = ScheduledTrainer(
        _trainer(n_clients=4, participation=0.5),
        SchedConfig(policy="deadline", overselect=1.0,
                    deadline_s=float("inf")))
    hd = dl.run(2)
    for a, b in zip(hs, hd):
        assert a["participants"] == b["participants"]
        assert b["dropped"] == []
        np.testing.assert_array_equal(np.asarray(a["rewards"]),
                                      np.asarray(b["rewards"]))
        assert a["round_duration"] == b["round_duration"]


@pytest.mark.slow
def test_deadline_drops_stragglers_and_saves_wallclock():
    """Bimodal heterogeneity: the quantile deadline drops slow edge
    clients and closes rounds far faster than the sync barrier."""
    mk = lambda: _trainer(n_clients=8, seed=1)  # noqa: E731
    sync = ScheduledTrainer(mk(), SchedConfig(policy="sync",
                                              profile="bimodal"))
    hs = sync.run(2)
    # bimodal is ~75% identically-slow edge clients, so the deadline
    # quantile must sit below the fast fraction (0.25) to cut the slow
    # mode off — a quantile at/above it lands on a slow-client time
    dl = ScheduledTrainer(
        mk(), SchedConfig(policy="deadline", profile="bimodal",
                          deadline_quantile=0.2))
    hd = dl.run(2)
    assert sum(len(e["dropped"]) for e in hd) > 0
    assert hd[-1]["sim_time"] < hs[-1]["sim_time"]
    assert all(np.isfinite(np.asarray(e["rewards"])).all() for e in hd)


# ------------------------------------------------- cohort dispatch
def test_build_cohorts_groups_by_static_config():
    import dataclasses
    base = FIRMConfig(local_steps=1)
    alt = dataclasses.replace(base, local_steps=3)
    plan = build_cohorts([(0, base), (1, alt), (2, base), (3, alt)])
    assert [c.members for c in plan] == [(0, 2), (1, 3)]
    assert plan[0].cfc.local_steps == 1 and plan[1].cfc.local_steps == 3
    # preference lifted to a traced array: stripped from the key
    p0 = dataclasses.replace(base, preference=(0.9, 0.1))
    p1 = dataclasses.replace(base, preference=(0.1, 0.9))
    assert len(build_cohorts([(0, p0), (1, p1)],
                             lift_preference=True)) == 1
    assert len(build_cohorts([(0, p0), (1, p1)],
                             lift_preference=False)) == 2


def test_cohort_dispatch_two_groups_one_round():
    """Heterogeneous client_local_steps (FedMOA-style rates) split into
    >= 2 distinct-config cohorts, each one vmapped program — no fallback
    to the per-client loop — and match the loop path's results."""
    kw = dict(n_clients=4, local_steps=2,
              client_local_steps=(1, 1, 2, 2))
    s_vec = _trainer(**kw).run_round()
    assert s_vec["cohorts"] == 2
    # 2 cohorts x (stack + round + unstack) + round-level tree ops —
    # far below the loop's C x K x 3 per-client dispatches
    assert s_vec["dispatches"] <= 12
    s_loop = _trainer(vectorized_clients=False, **kw).run_round()
    assert s_loop["dispatches"] >= 6 * 3
    np.testing.assert_allclose(np.asarray(s_vec["rewards"]),
                               np.asarray(s_loop["rewards"]), atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_vec["per_client_lam"]),
                               np.asarray(s_loop["per_client_lam"]),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_vec["rewards_per_client"]),
                               np.asarray(s_loop["rewards_per_client"]),
                               atol=1e-5)
    assert s_vec["comm_bytes"] == s_loop["comm_bytes"]


def test_uniform_client_local_steps_override_single_cohort():
    """A UNIFORM client_local_steps override forms one cohort whose K
    differs from fc.local_steps — the vec path must honor the cohort's
    K, not the base config's (regression: it trained K=base silently)."""
    kw = dict(n_clients=2, local_steps=1, client_local_steps=(2, 2))
    s_vec = _trainer(**kw).run_round()
    assert s_vec["cohorts"] == 1
    assert s_vec["local_steps"] == [2, 2]
    s_loop = _trainer(vectorized_clients=False, **kw).run_round()
    np.testing.assert_allclose(np.asarray(s_vec["rewards"]),
                               np.asarray(s_loop["rewards"]), atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_vec["per_client_lam"]),
                               np.asarray(s_loop["per_client_lam"]),
                               atol=1e-4)


@pytest.mark.slow
def test_cohort_dispatch_multi_round_stays_close():
    kw = dict(n_clients=4, local_steps=2,
              client_local_steps=(1, 2, 1, 2))
    h_vec = _trainer(**kw).run(2)
    h_loop = _trainer(vectorized_clients=False, **kw).run(2)
    for a, b in zip(h_vec, h_loop):
        np.testing.assert_allclose(np.asarray(a["rewards"]),
                                   np.asarray(b["rewards"]), atol=2e-2)
        assert a["comm_bytes"] == b["comm_bytes"]


def test_fedcmoo_rejects_heterogeneous_local_steps():
    with pytest.raises(ValueError, match="fedcmoo"):
        _trainer(algorithm="fedcmoo", n_clients=2,
                 client_local_steps=(1, 2))


def test_scheduler_rejects_unknown_policy_and_fedcmoo_fedbuff():
    with pytest.raises(ValueError, match="policy"):
        ScheduledTrainer(_trainer(), SchedConfig(policy="psychic"))
    st = ScheduledTrainer(_trainer(algorithm="fedcmoo"),
                          SchedConfig(policy="fedbuff"))
    with pytest.raises(ValueError, match="fedbuff"):
        st.run(1)
