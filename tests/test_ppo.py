"""Multi-objective PPO machinery: logprobs, GAE, shared-forward VJP,
critics, KL controller, rewards."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import FIRMConfig
from repro.models import transformer as T
from repro.models.common import split_trainable
from repro.rlhf import critic as critic_lib
from repro.rlhf import kl as kl_lib
from repro.rlhf import ppo, rewards as rewards_lib

KEY = jax.random.PRNGKey(0)


def test_token_logprobs_manual():
    logits = jax.random.normal(KEY, (1, 4, 7))
    tokens = jnp.asarray([[1, 3, 0, 5]])
    lp = ppo.token_logprobs(logits, tokens)
    assert lp.shape == (1, 4)
    assert float(lp[0, 0]) == 0.0
    want = jax.nn.log_softmax(logits[0, 1])[0]   # token at pos 2 from logits 1
    np.testing.assert_allclose(float(lp[0, 2]), float(want), rtol=1e-5)


def test_gae_matches_naive_loop():
    b, s, m = 2, 6, 2
    gamma, lam = 0.95, 0.9
    r = jax.random.normal(KEY, (b, s, m))
    v = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, m))
    mask = jnp.ones((b, s))
    adv, ret = ppo.gae(r, v, mask, gamma, lam)
    # naive reference
    adv_ref = np.zeros((b, s, m))
    r_, v_ = np.asarray(r), np.asarray(v)
    for bi in range(b):
        last = np.zeros(m)
        for t in reversed(range(s)):
            v_next = v_[bi, t + 1] if t + 1 < s else np.zeros(m)
            nm = 1.0 if t + 1 < s else 0.0
            delta = r_[bi, t] + gamma * v_next * nm - v_[bi, t]
            last = delta + gamma * lam * nm * last
            adv_ref[bi, t] = last
    np.testing.assert_allclose(np.asarray(adv), adv_ref, rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(ret), adv_ref + v_, rtol=1e-4,
                               atol=1e-5)


def test_shaped_rewards_terminal_placement():
    mask = jnp.asarray([[0.0, 1.0, 1.0, 0.0]])
    kl = jnp.zeros((1, 4))
    rw = jnp.asarray([[0.7, 0.3]])
    r_tok = ppo.shaped_rewards(kl, mask, rw, jnp.asarray(0.1))
    # terminal reward lands on the LAST response position (index 2)
    np.testing.assert_allclose(np.asarray(r_tok[0, 2]), [0.7, 0.3],
                               rtol=1e-6)
    assert float(jnp.abs(r_tok[0, 0]).sum()) == 0.0
    assert float(jnp.abs(r_tok[0, 3]).sum()) == 0.0


def _tiny_setup():
    cfg = get_config("llama-3.2-1b").reduced(n_layers=2, d_model=64,
                                             vocab=128)
    params = T.init_params(cfg, KEY, dtype=jnp.float32)
    trainable, frozen = split_trainable(params)
    fc = FIRMConfig(batch_size=2)
    b, s = 2, 12
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    mask = jnp.concatenate([jnp.zeros((b, 4)), jnp.ones((b, 8))], 1)
    lp = -2.0 * jnp.ones((b, s))
    batch = ppo.PPOBatch(tokens, mask.astype(jnp.float32), lp, lp,
                         jax.random.uniform(KEY, (b, 2)))
    critic = critic_lib.init_critic(2, cfg.d_model)
    return cfg, fc, trainable, frozen, critic, batch


@pytest.mark.slow
def test_per_objective_grads_match_individual_jax_grad():
    """The shared-forward M-pull VJP == M independent jax.grad calls."""
    cfg, fc, trainable, frozen, critic, batch = _tiny_setup()
    kl_coef = jnp.asarray(0.1)
    grads, losses, _ = ppo.per_objective_grads(
        cfg, fc, trainable, frozen, critic, batch, kl_coef)
    for j in range(2):
        def loss_j(tr, j=j):
            ls, _ = ppo.multi_objective_losses(
                cfg, fc, tr, frozen, critic, batch, kl_coef)
            return ls[j]
        g_ref = jax.grad(loss_j)(trainable)
        for a, b_ in zip(jax.tree_util.tree_leaves(grads[j]),
                         jax.tree_util.tree_leaves(g_ref)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       rtol=1e-4, atol=1e-6)


def test_ppo_losses_finite_and_distinct():
    cfg, fc, trainable, frozen, critic, batch = _tiny_setup()
    losses, _ = ppo.multi_objective_losses(
        cfg, fc, trainable, frozen, critic, batch, jnp.asarray(0.1))
    assert losses.shape == (2,)
    assert np.isfinite(np.asarray(losses)).all()


def test_critic_projection_bound():
    c = {"w": 100.0 * jnp.ones((2, 8))}
    r_w = 3.0
    p = critic_lib.project(c, r_w)
    norms = np.linalg.norm(np.asarray(p["w"]), axis=-1)
    assert (norms <= r_w + 1e-5).all()


def test_critic_td_learns_constant_reward():
    """TD on a constant positive reward pushes values up."""
    key = KEY
    b, s, d, m = 4, 8, 16, 2
    feats = critic_lib.features(jax.random.normal(key, (b, s, d)))
    critic = critic_lib.init_critic(m, d)
    r_tok = jnp.ones((b, s, m))
    mask = jnp.ones((b, s))
    v0 = float(critic_lib.values(critic, feats).mean())
    for _ in range(50):
        critic, err = critic_lib.td_update(critic, feats, r_tok, mask,
                                           0.9, 0.5, r_w=20.0)
    v1 = float(critic_lib.values(critic, feats).mean())
    assert v1 > v0


def test_features_norm_bounded():
    h = 100.0 * jax.random.normal(KEY, (2, 5, 8))
    f = critic_lib.features(h)
    assert float(jnp.linalg.norm(f, axis=-1).max()) <= 1.0 + 1e-5


def test_adaptive_kl_direction():
    c = jnp.asarray(0.2)
    up = kl_lib.adaptive_kl_update(c, jnp.asarray(0.5), target=0.03)
    down = kl_lib.adaptive_kl_update(c, jnp.asarray(0.0), target=0.03)
    assert float(up) > 0.2 > float(down)


def test_rewards_in_unit_interval_and_conflicting():
    fns = rewards_lib.make_reward_fns(1000, 3)
    key = KEY
    toks = jax.random.randint(key, (16, 32), 0, 1000)
    mask = jnp.ones((16, 32))
    r = rewards_lib.score_batch(fns, toks, mask)
    assert r.shape == (16, 3)
    assert float(r.min()) >= 0.0 and float(r.max()) <= 1.0
    # conflict: tokens entirely inside the harmful/helpful overlap band
    overlap = jnp.full((4, 32), int(1000 * 0.47))
    r2 = rewards_lib.score_batch(fns, overlap, jnp.ones((4, 32)))
    assert float(r2[:, 0].mean()) > 0.9      # very helpful
    assert float(r2[:, 1].mean()) < 0.2      # very harmful


def test_heterogeneous_rm_variants_differ():
    f1 = rewards_lib.make_reward_fns(1000, 2, variant="default")
    f2 = rewards_lib.make_reward_fns(1000, 2, variant="alt")
    toks = jax.random.randint(KEY, (8, 16), 0, 1000)
    mask = jnp.ones((8, 16))
    r1 = rewards_lib.score_batch(f1, toks, mask)
    r2 = rewards_lib.score_batch(f2, toks, mask)
    assert not np.allclose(np.asarray(r1), np.asarray(r2))
