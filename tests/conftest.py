import os

# Tests run on the single real CPU device.  The 512-device override is
# reserved for repro.launch.dryrun (see its module docstring).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
