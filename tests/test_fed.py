"""Federated protocol: FedAvg, engine rounds for every algorithm,
communication accounting (the O(Cd) vs O(CMd) claim), checkpointing,
data partitioning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import FIRMConfig
from repro.core import comms, fedavg, fedcmoo
from repro.data import partition
from repro.fed.engine import EngineConfig, FederatedTrainer
from repro.train import checkpoint

KEY = jax.random.PRNGKey(0)


def test_fedavg_is_mean():
    trees = [{"a": jnp.full((3,), float(i)), "b": {"c": jnp.ones((2, 2)) * i}}
             for i in range(4)]
    avg = fedavg.fedavg(trees)
    np.testing.assert_allclose(np.asarray(avg["a"]), [1.5] * 3)
    np.testing.assert_allclose(np.asarray(avg["b"]["c"]), 1.5)


def test_fedavg_weighted():
    trees = [{"a": jnp.zeros(2)}, {"a": jnp.ones(2)}]
    w = fedavg.fedavg_weighted(trees, [1.0, 3.0])
    np.testing.assert_allclose(np.asarray(w["a"]), 0.75)


def test_comm_accounting_firm_vs_fedcmoo():
    d, c, m, k = 1000, 8, 3, 4
    f = comms.firm_round_bytes(d, c, k)
    s = comms.fedcmoo_round_bytes(d, c, m, k)
    # FIRM is independent of M and K; FedCMOO pays M*K gradients
    assert f["total"] == 2 * c * d * 4
    assert s["total"] > f["total"] * m
    compressed = comms.fedcmoo_round_bytes(d, c, m, k, compress_rank=10)
    assert compressed["total"] < s["total"]


def _tiny_trainer(algorithm, **kw):
    cfg = get_config("llama-3.2-1b").reduced(n_layers=2, d_model=64,
                                             vocab=256)
    fc = FIRMConfig(n_objectives=2, n_clients=2, local_steps=1,
                    batch_size=2, beta=0.05)
    ec = EngineConfig(algorithm=algorithm, max_new=6, prompt_len=4, **kw)
    return FederatedTrainer(cfg, fc, ec)


@pytest.mark.parametrize("alg", [
    "firm", "firm_unreg",
    pytest.param("fedcmoo", marks=pytest.mark.slow),
    pytest.param("linear", marks=pytest.mark.slow)])
def test_engine_round_all_algorithms(alg):
    tr = _tiny_trainer(alg)
    s = tr.run(1)[-1]
    assert s["rewards"].shape == (2,)
    assert np.isfinite(s["rewards"]).all()
    assert s["comm_bytes"] > 0


@pytest.mark.slow
def test_engine_measured_comm_ratio():
    """Measured ledger bytes: FedCMOO sends M gradients per local step on
    top of the param sync -> strictly more than FIRM."""
    firm = _tiny_trainer("firm")
    firm.run(1)
    fed = _tiny_trainer("fedcmoo")
    fed.run(1)
    assert fed.ledger.total > firm.ledger.total
    # gradient tree size == adapter size d; FedCMOO extra = C * M * d * K
    d = firm.d_trainable
    extra = fed.ledger.total - firm.ledger.total
    assert extra == 2 * 2 * d * 4  # C=2 clients, M=2 objectives, K=1, f32


def test_engine_heterogeneous_rms_runs():
    tr = _tiny_trainer("firm", heterogeneous_rms=True)
    s = tr.run(1)[-1]
    assert np.isfinite(s["rewards"]).all()


@pytest.mark.slow
def test_fedcmoo_single_lambda_shared():
    tr = _tiny_trainer("fedcmoo")
    s = tr.run(1)[-1]
    lams = s["per_client_lam"]
    np.testing.assert_allclose(lams[0], lams[1], atol=1e-6)
    assert s["lam_disagreement"] < 1e-6


def test_fedcmoo_sketch_gram_close():
    key = KEY
    flat = jax.random.normal(key, (2, 5000))
    sk = fedcmoo.sketch(flat, 2000, key)
    from repro.core.mgda import gram_matrix
    g1 = np.asarray(gram_matrix(flat))
    g2 = np.asarray(gram_matrix(sk))
    np.testing.assert_allclose(g1, g2, rtol=0.25, atol=20.0)


def test_dirichlet_partition_heterogeneity_monotone():
    hi = partition.dirichlet_topic_mixtures(16, alpha=0.05, seed=1)
    lo = partition.dirichlet_topic_mixtures(16, alpha=100.0, seed=1)
    assert float(partition.heterogeneity_stat(hi)) > \
        float(partition.heterogeneity_stat(lo))


def test_prompt_topics_respect_bands():
    from repro.data.prompts import sample_prompts
    vocab, n_topics = 800, 8
    band = vocab // n_topics
    topics = jnp.asarray([0] * 64)
    toks = sample_prompts(KEY, topics, 16, vocab)
    frac_in_band = float(((toks >= 0) & (toks < band)).mean())
    # topic band is strongly over-represented vs uniform (1/8 = 0.125)
    assert frac_in_band > 0.35


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)},
            "t": (jnp.zeros((2,)), jnp.ones((1,), jnp.int32))}
    p = str(tmp_path / "ck.npz")
    checkpoint.save(p, tree, step=7)
    got, step = checkpoint.restore(p, tree)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


@pytest.mark.slow
def test_firm_beta_reduces_drift_vs_unreg():
    """RQ2 at micro scale: over a few rounds, the regularized run keeps
    client lambdas closer together than beta=0."""
    reg = _tiny_trainer("firm")
    unreg = _tiny_trainer("firm_unreg")
    r1 = np.mean([s["lam_disagreement"] for s in reg.run(3)])
    r2 = np.mean([s["lam_disagreement"] for s in unreg.run(3)])
    # allow noise but regularized should not be dramatically worse
    assert r1 <= r2 * 1.5 + 0.05


def test_partial_participation():
    """Beyond-paper: only a sampled subset of clients trains each round."""
    cfg = get_config("llama-3.2-1b").reduced(n_layers=2, d_model=64,
                                             vocab=256)
    fc = FIRMConfig(n_objectives=2, n_clients=4, local_steps=1,
                    batch_size=2, beta=0.05, participation=0.5)
    tr = FederatedTrainer(cfg, fc, EngineConfig(max_new=6, prompt_len=4))
    s = tr.run(1)[-1]
    assert len(s["participants"]) == 2
    assert s["per_client_lam"].shape == (2, 2)


@pytest.mark.slow
def test_pluralistic_client_preferences():
    """Beyond-paper (paper §6 future work): per-client preference vectors
    steer each client's lambda independently."""
    cfg = get_config("llama-3.2-1b").reduced(n_layers=2, d_model=64,
                                             vocab=256)
    fc = FIRMConfig(n_objectives=2, n_clients=2, local_steps=1,
                    batch_size=2, beta=0.05,
                    client_preferences=((4.0, 0.25), (0.25, 4.0)))
    tr = FederatedTrainer(cfg, fc, EngineConfig(max_new=6, prompt_len=4))
    s = tr.run(2)[-1]
    lams = s["per_client_lam"]
    assert lams[0, 0] > lams[1, 0]
