"""Vectorized round engine: loop-vs-vmapped equivalence, stacked tree
ops, batched prompt sampling, banded rewards, and buffer donation."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import FIRMConfig
from repro.core import drift, fedavg
from repro.data import partition
from repro.fed.engine import EngineConfig, FederatedTrainer
from repro.rlhf import rewards as rewards_lib

KEY = jax.random.PRNGKey(0)


def _cfg():
    return get_config("llama-3.2-1b").reduced(n_layers=2, d_model=64,
                                              vocab=256)


def _trainer(algorithm, vectorized, *, n_clients=2, local_steps=1,
             m=2, seed=0, **kw):
    fc_kw = {k: kw.pop(k) for k in ("client_preferences", "participation")
             if k in kw}
    fc = FIRMConfig(n_objectives=m, n_clients=n_clients,
                    local_steps=local_steps, batch_size=2, beta=0.05,
                    **fc_kw)
    ec = EngineConfig(algorithm=algorithm, max_new=6, prompt_len=4,
                      seed=seed, vectorized_clients=vectorized, **kw)
    return FederatedTrainer(_cfg(), fc, ec)


def _assert_summaries_close(s0, s1, atol=1e-3):
    np.testing.assert_allclose(s0["rewards"], s1["rewards"], atol=atol)
    np.testing.assert_allclose(s0["per_client_lam"], s1["per_client_lam"],
                               atol=atol)
    np.testing.assert_allclose(s0["param_drift"], s1["param_drift"],
                               atol=atol)
    np.testing.assert_allclose(s0["kl"], s1["kl"], atol=atol)
    assert s0["comm_bytes"] == s1["comm_bytes"]
    assert s0["participants"] == s1["participants"]


# -------------------------------------------------- loop vs vectorized
@pytest.mark.parametrize("alg", ["firm", "fedcmoo", "linear"])
def test_loop_vs_vectorized_one_round(alg):
    """Same seed, vectorized_clients on/off: per-round rewards, λ, drift
    and comm bytes agree (firm/fedcmoo/linear)."""
    s0 = _trainer(alg, False, local_steps=2).run(1)[-1]
    s1 = _trainer(alg, True, local_steps=2).run(1)[-1]
    _assert_summaries_close(s0, s1)


@pytest.mark.slow
@pytest.mark.parametrize("alg", ["firm", "fedcmoo", "linear"])
def test_loop_vs_vectorized_multi_round(alg):
    # λ accumulates float noise through the trace-normalized Gram + QP
    # solve across rounds (rewards stay bit-identical); tolerance is loose
    h0 = _trainer(alg, False, local_steps=2, n_clients=2).run(3)
    h1 = _trainer(alg, True, local_steps=2, n_clients=2).run(3)
    for s0, s1 in zip(h0, h1):
        _assert_summaries_close(s0, s1, atol=2e-2)


def test_loop_vs_vectorized_heterogeneous_rms():
    """Per-client reward bands ride the vmapped scorer as traced params."""
    s0 = _trainer("firm", False, n_clients=2,
                  heterogeneous_rms=True).run(1)[-1]
    s1 = _trainer("firm", True, n_clients=2,
                  heterogeneous_rms=True).run(1)[-1]
    _assert_summaries_close(s0, s1)


@pytest.mark.slow
def test_loop_vs_vectorized_client_preferences():
    """Per-client preference vectors become a traced (C, M) array in the
    vectorized path instead of per-client static retraces."""
    prefs = ((4.0, 0.25), (0.25, 4.0))
    s0 = _trainer("firm", False, client_preferences=prefs).run(2)[-1]
    s1 = _trainer("firm", True, client_preferences=prefs).run(2)[-1]
    _assert_summaries_close(s0, s1, atol=5e-3)
    # the preference steering effect survives vectorization
    assert s1["per_client_lam"][0, 0] > s1["per_client_lam"][1, 0]


def test_loop_vs_vectorized_partial_participation():
    s0 = _trainer("firm", False, n_clients=4, participation=0.5).run(1)[-1]
    s1 = _trainer("firm", True, n_clients=4, participation=0.5).run(1)[-1]
    assert len(s1["participants"]) == 2
    _assert_summaries_close(s0, s1)


def test_vectorized_flag_off_uses_loop():
    tr = _trainer("firm", False)
    assert not tr._use_vectorized()
    assert _trainer("firm", True)._use_vectorized()


def test_vectorized_dispatch_count_flat_in_clients():
    """The vectorized local phase is ONE jitted dispatch regardless of C;
    the loop path pays C × K × (generate + ref + step)."""
    s_vec = _trainer("firm", True, n_clients=4, local_steps=2).run(1)[-1]
    s_loop = _trainer("firm", False, n_clients=4, local_steps=2).run(1)[-1]
    assert s_vec["dispatches"] < s_loop["dispatches"]
    # loop: 3 jitted calls per client-step + round-level tree ops
    assert s_loop["dispatches"] >= 4 * 2 * 3
    # vectorized: stack, round scan, unstack + round-level tree ops
    assert s_vec["dispatches"] <= 8


# -------------------------------------------------- component equivalence
def test_sample_prompt_block_matches_datasets():
    """The batched (C, B, P) sampler reproduces each client's
    PromptDataset.next_batch stream bit-for-bit, including desynced
    per-client counts."""
    vocab, plen, b = 256, 4, 3
    datasets = partition.make_client_datasets(3, vocab, plen, seed=5)
    datasets[1].next_batch(b)                # desync client 1's stream
    seeds = [ds.seed for ds in datasets]
    counts = [ds._count for ds in datasets]
    probs = jnp.stack([ds.topic_probs for ds in datasets])
    block = partition.sample_prompt_block(seeds, counts, probs, b, plen,
                                          vocab)
    assert block.shape == (3, b, plen)
    for c, ds in enumerate(datasets):
        np.testing.assert_array_equal(np.asarray(block[c]),
                                      np.asarray(ds.next_batch(b)))


def test_score_batch_banded_matches_closures():
    for variant in ("default", "alt"):
        fns = rewards_lib.make_reward_fns(256, 3, variant=variant,
                                          length_tolerance=5)
        helpful, harmful = rewards_lib.variant_bands(256, variant)
        tokens = jax.random.randint(KEY, (4, 10), 0, 256)
        mask = (jax.random.uniform(jax.random.fold_in(KEY, 1),
                                   (4, 10)) > 0.3).astype(jnp.float32)
        want = rewards_lib.score_batch(fns, tokens, mask)
        got = rewards_lib.score_batch_banded(helpful, harmful, tokens,
                                             mask, 3, 5)
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


def test_score_batch_banded_vmaps_over_clients():
    bands = [rewards_lib.variant_bands(256, v)
             for v in ("default", "alt")]
    bh = jnp.stack([b[0] for b in bands])
    bx = jnp.stack([b[1] for b in bands])
    tokens = jax.random.randint(KEY, (2, 4, 10), 0, 256)
    mask = jnp.ones((2, 4, 10), jnp.float32)
    out = jax.vmap(
        lambda h, x, t, mk: rewards_lib.score_batch_banded(h, x, t, mk,
                                                           2, 5))(
        bh, bx, tokens, mask)
    assert out.shape == (2, 4, 2)
    for c in range(2):
        fns = rewards_lib.make_reward_fns(
            256, 2, variant=("default", "alt")[c], length_tolerance=5)
        np.testing.assert_allclose(
            np.asarray(out[c]),
            np.asarray(rewards_lib.score_batch(fns, tokens[c], mask[c])))


def test_stack_unstack_roundtrip():
    trees = [{"a": jnp.full((3,), float(i)),
              "b": {"c": jnp.ones((2, 2)) * i}} for i in range(4)]
    stacked = fedavg.stack_trees(trees)
    assert stacked["a"].shape == (4, 3)
    back = fedavg.unstack_tree(stacked, 4)
    for t0, t1 in zip(trees, back):
        for a, b in zip(jax.tree_util.tree_leaves(t0),
                        jax.tree_util.tree_leaves(t1)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_allclose(
        np.asarray(fedavg.fedavg_stacked(stacked)["a"]),
        np.asarray(fedavg.fedavg(trees)["a"]), rtol=1e-6)


def test_param_drift_stacked_matches_loop():
    keys = jax.random.split(KEY, 3)
    trees = [{"w": jax.random.normal(k, (5, 4)),
              "v": jax.random.normal(jax.random.fold_in(k, 1), (7,))}
             for k in keys]
    want = float(drift.param_drift(trees))
    got = float(drift.param_drift_stacked(fedavg.stack_trees(trees)))
    np.testing.assert_allclose(got, want, rtol=1e-5)
    single = fedavg.stack_trees(trees[:1])
    assert float(drift.param_drift_stacked(single)) == 0.0


def test_generate_stacked_matches_per_client():
    """The standalone batched-generation API reproduces per-client
    generate calls with the same keys over a (C, B, P) block."""
    from repro.fed.engine import _stack_trees_jit
    from repro.models import transformer
    from repro.rlhf.sampling import generate, generate_stacked
    cfg = _cfg()
    keys = jax.random.split(KEY, 2)
    params = [transformer.init_params(cfg, k) for k in keys]
    prompts = jax.random.randint(jax.random.fold_in(KEY, 2), (2, 3, 4),
                                 0, cfg.vocab)
    gkeys = jax.random.split(jax.random.fold_in(KEY, 3), 2)
    toks, lps, mask = generate_stacked(cfg, _stack_trees_jit(*params),
                                       prompts, gkeys, max_new=5)
    assert toks.shape == (2, 3, 9)
    for c in range(2):
        t, lp, mk = generate(cfg, params[c], prompts[c], gkeys[c],
                             max_new=5)
        np.testing.assert_array_equal(np.asarray(toks[c]), np.asarray(t))
        np.testing.assert_allclose(np.asarray(lps[c]), np.asarray(lp),
                                   atol=1e-5)
        np.testing.assert_array_equal(np.asarray(mask[c]), np.asarray(mk))


# -------------------------------------------------- buffer donation
def test_no_donation_warnings():
    """The donated client-state buffers must actually be consumed: any
    'donated buffers were not usable' warning is an error."""
    with warnings.catch_warnings():
        warnings.filterwarnings("error", message=".*[Dd]onat.*")
        _trainer("firm", True, local_steps=2).run(1)
        _trainer("firm", False, local_steps=2).run(1)


def test_loop_path_broadcast_survives_donation():
    """The jitted local step donates its state arg; the broadcast anchor
    (and other clients' states) must not be invalidated — two rounds with
    multiple clients would raise on a deleted buffer otherwise."""
    tr = _trainer("firm", False, n_clients=3, local_steps=2)
    h = tr.run(2)
    assert np.isfinite(h[-1]["rewards"]).all()
