"""Capability-driven run API: plan-selection matrix, capability
validation, backward-compat shims, and golden ExecutionPlan snapshots.

The matrix test pins every fallback decision the engine used to hard-code
(host-exchange algorithm -> no fused, sync + single cohort -> fused,
het-K -> multi-cohort vectorized, ...) as a pure ``plan()`` outcome; the
golden test serializes plan summaries for a small config matrix and
diffs them against ``tests/golden_plans.json`` so a config silently
falling back to the per-client loop fails PRs (regenerate with
``PYTHONPATH=src python scripts/update_golden_plans.py``).
"""
import json
import pathlib

import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import FIRMConfig, SchedConfig
from repro.fed import api
from repro.fed.algorithms import (Algorithm, Capabilities,
                                  available_algorithms, get_algorithm,
                                  register_algorithm)
from repro.fed.api import EngineConfig, RunSpec
from repro.fed.engine import FederatedTrainer

GOLDEN = pathlib.Path(__file__).parent / "golden_plans.json"


def _cfg():
    return get_config("llama-3.2-1b").reduced(n_layers=2, d_model=64,
                                              vocab=256)


def _spec(algorithm="firm", *, n_clients=2, local_steps=1, m=2, seed=0,
          sched=None, rounds=None, **kw):
    fc_kw = {k: kw.pop(k) for k in ("client_preferences", "participation",
                                    "client_local_steps") if k in kw}
    fc = FIRMConfig(n_objectives=m, n_clients=n_clients,
                    local_steps=local_steps, batch_size=2, beta=0.05,
                    **fc_kw)
    ec = EngineConfig(algorithm=algorithm, max_new=6, prompt_len=4,
                      seed=seed, **kw)
    return RunSpec(model=_cfg(), firm=fc, engine=ec, sched=sched,
                   rounds=rounds)


# The config matrix the golden snapshot pins (name -> RunSpec).  Keep
# entries deterministic: plan() touches no RNG beyond shape tracing.
def golden_matrix():
    return {
        "firm_fused": _spec("firm", n_clients=4, fused_rounds=4, rounds=8),
        "firm_per_round": _spec("firm", n_clients=4),
        "firm_loop": _spec("firm", n_clients=4, vectorized_clients=False),
        "firm_het_k": _spec("firm", n_clients=4, fused_rounds=4,
                            client_local_steps=(1, 2, 1, 2)),
        "firm_unreg_fused": _spec("firm_unreg", n_clients=2,
                                  fused_rounds=2),
        "linear_int8ef_fused": _spec("linear", n_clients=2, fused_rounds=2,
                                     uplink_codec="int8+ef"),
        "fedcmoo_no_fused": _spec("fedcmoo", n_clients=4, local_steps=2,
                                  fused_rounds=4),
        "firm_deadline": _spec("firm", n_clients=4, fused_rounds=4,
                               sched=SchedConfig(policy="deadline",
                                                 overselect=1.5,
                                                 deadline_quantile=0.5)),
        "firm_fedbuff_int8ef": _spec("firm", n_clients=4,
                                     uplink_codec="int8+ef",
                                     sched=SchedConfig(policy="fedbuff",
                                                       buffer_size=2)),
        "firm_partial_participation": _spec("firm", n_clients=4,
                                            participation=0.5,
                                            fused_rounds=4),
    }


# ------------------------------------------------- plan-selection matrix
@pytest.mark.parametrize("name,expected_executor,expected_cohorts", [
    ("firm_fused", "fused", 1),
    ("firm_per_round", "vectorized", 1),
    ("firm_loop", "loop", 0),
    ("firm_het_k", "vectorized", 2),        # het-K -> multi-cohort, no fuse
    ("firm_unreg_fused", "fused", 1),
    ("linear_int8ef_fused", "fused", 1),
    ("fedcmoo_no_fused", "vectorized", 1),  # host exchange -> never fused
    ("firm_deadline", "vectorized", 1),     # clock-driven -> per-round
    ("firm_fedbuff_int8ef", "vectorized", 1),
    ("firm_partial_participation", "fused", 1),
])
def test_executor_matrix(name, expected_executor, expected_cohorts):
    plan = api.plan(golden_matrix()[name])
    assert plan.executor == expected_executor, plan.reasons
    assert len(plan.cohorts) == expected_cohorts


def test_plan_reproduces_engine_fallbacks_capability_only():
    """The plan's executor equals what the trainer actually resolves —
    both go through the same capability queries, never name strings."""
    for name in ("firm_fused", "fedcmoo_no_fused", "firm_het_k",
                 "firm_loop"):
        spec = golden_matrix()[name]
        plan = api.plan(spec)
        tr = FederatedTrainer(spec.model, spec.firm, spec.engine)
        fused = tr.ec.fused_rounds > 1 and tr._fused_mode()[0]
        mode, _ = tr._local_phase_mode(list(range(spec.firm.n_clients)))
        want = ("fused" if fused
                else "loop" if mode == "loop" else "vectorized")
        assert plan.executor == want, (name, plan.reasons)


def test_plan_partial_participation_counts():
    plan = api.plan(golden_matrix()["firm_partial_participation"])
    assert plan.n_clients == 4
    assert plan.participants_per_round == 2


def test_plan_fused_chunking_partial_tail():
    plan = api.plan(_spec("firm", fused_rounds=3, rounds=7))
    assert plan.fused_chunks == (3, 3, 1)


def test_plan_validates_like_execution():
    with pytest.raises(ValueError, match="fedcmoo"):
        api.plan(_spec("fedcmoo", n_clients=2, client_local_steps=(1, 2)))
    with pytest.raises(ValueError, match="fedbuff"):
        api.plan(_spec("fedcmoo", sched=SchedConfig(policy="fedbuff")))
    with pytest.raises(ValueError, match="policy"):
        api.plan(_spec("firm", sched=SchedConfig(policy="psychic")))
    with pytest.raises(ValueError, match="unknown algorithm"):
        api.plan(_spec("adam"))


# ------------------------------------------------- capability validation
def test_fusable_requires_traced_server_exchange():
    class Bad(Algorithm):
        name = "bad_fusable"
        kernel = "bad_fusable"
        caps = Capabilities(fusable=True, traced_server_exchange=False,
                            single_cohort_required=True)

    with pytest.raises(ValueError, match="traced_server_exchange"):
        register_algorithm(Bad())
    assert "bad_fusable" not in available_algorithms()


def test_fusable_requires_vmap_safe():
    class Bad(Algorithm):
        name = "bad_vmap"
        kernel = "bad_vmap"
        caps = Capabilities(fusable=True, vmap_safe=False)

    with pytest.raises(ValueError, match="vmap_safe"):
        register_algorithm(Bad())


def test_non_vmap_safe_algorithm_plans_loop():
    """A registered algorithm declaring vmap_safe=False must resolve to
    the per-client loop (and never fuse) purely from its capabilities."""
    class LoopOnly(Algorithm):
        name = "_test_loop_only"
        kernel = "_test_loop_only"
        caps = Capabilities(vmap_safe=False, fusable=False)

    register_algorithm(LoopOnly())
    try:
        plan = api.plan(_spec("_test_loop_only", fused_rounds=4))
        assert plan.executor == "loop"
        assert plan.local_mode == "loop"
    finally:
        from repro.fed.algorithms import _REGISTRY
        del _REGISTRY["_test_loop_only"]


def test_registry_roundtrip():
    assert set(available_algorithms()) >= {"firm", "firm_unreg", "linear",
                                           "fedcmoo"}
    assert get_algorithm("firm_unreg").kernel == "firm"
    assert get_algorithm("fedcmoo").caps.single_cohort_required


# --------------------------------------------------- backward-compat shims
def test_front_door_matches_direct_trainer_bit_identical():
    """plan().build()/execute() and the legacy FederatedTrainer(...) entry
    point produce bit-identical histories and aggregates."""
    spec = _spec("firm", n_clients=2, rounds=2)
    h0 = api.execute(api.plan(spec))
    tr = FederatedTrainer(spec.model, spec.firm,
                          EngineConfig(algorithm="firm", max_new=6,
                                       prompt_len=4, seed=0))
    h1 = tr.run(2)
    assert len(h0) == len(h1) == 2
    for a, b in zip(h0, h1):
        np.testing.assert_array_equal(np.asarray(a["rewards"]),
                                      np.asarray(b["rewards"]))
        np.testing.assert_array_equal(np.asarray(a["per_client_lam"]),
                                      np.asarray(b["per_client_lam"]))
        assert a["comm_bytes"] == b["comm_bytes"]
        assert a["participants"] == b["participants"]
        assert a["dispatches"] == b["dispatches"]


def test_run_round_summary_keys_stable():
    """The run_round result dict keeps its public keys (source compat)."""
    tr = FederatedTrainer(_cfg(),
                          FIRMConfig(n_objectives=2, n_clients=2,
                                     local_steps=1, batch_size=2,
                                     beta=0.05),
                          EngineConfig(max_new=6, prompt_len=4))
    s = tr.run_round()
    for key in ("rewards", "lam_mean", "lam_disagreement", "param_drift",
                "kl", "comm_bytes", "up_bytes", "down_bytes",
                "participants", "per_client_lam", "rewards_per_client",
                "dispatches", "up_nbytes", "down_nbytes", "local_steps",
                "cohorts"):
        assert key in s, key


def test_scheduled_trainer_refreshes_legacy_plan():
    """Wrapping a legacy-constructed trainer in ScheduledTrainer
    re-resolves trainer.plan under the actual policy (deadline/fedbuff
    force per-round even when the bare engine would fuse)."""
    from repro.fed.sched.policies import ScheduledTrainer
    tr = FederatedTrainer(_cfg(),
                          FIRMConfig(n_objectives=2, n_clients=2,
                                     local_steps=1, batch_size=2,
                                     beta=0.05),
                          EngineConfig(max_new=6, prompt_len=4,
                                       fused_rounds=4))
    assert tr.plan.executor == "fused"         # self-planned without sched
    st = ScheduledTrainer(tr, SchedConfig(policy="deadline"))
    assert st.trainer.plan.policy == "deadline"
    assert st.trainer.plan.executor == "vectorized"


def test_benchmark_make_trainer_rides_front_door():
    """benchmarks.common.make_trainer routes through RunSpec/plan and
    stays bit-identical to direct construction (shared BENCH cells)."""
    from benchmarks.common import make_trainer
    tr0 = make_trainer("firm", n_clients=2, local_steps=1, batch=2)
    assert tr0.plan.executor == "vectorized"
    h0 = tr0.run(1)
    tr1 = FederatedTrainer(
        _cfg(), FIRMConfig(n_objectives=2, n_clients=2, local_steps=1,
                           batch_size=2, beta=0.05),
        EngineConfig(algorithm="firm", max_new=8, prompt_len=4))
    h1 = tr1.run(1)
    np.testing.assert_array_equal(np.asarray(h0[0]["rewards"]),
                                  np.asarray(h1[0]["rewards"]))
    assert h0[0]["comm_bytes"] == h1[0]["comm_bytes"]


# ------------------------------------------------- byte-model exactness
@pytest.mark.parametrize("codec", ["identity", "int8+ef"])
def test_plan_bytes_match_measured_ledger(codec):
    """plan() predicted the ledger exactly, before compilation."""
    spec = _spec("firm", n_clients=2, uplink_codec=codec,
                 downlink_codec="int8")
    plan = api.plan(spec)
    tr = plan.build()
    s = tr.run_round()
    assert s["up_bytes"] == plan.up_bytes_per_round
    assert s["down_bytes"] == plan.down_bytes_per_round


@pytest.mark.slow
def test_plan_bytes_match_measured_fedcmoo():
    """Per-step gradient uploads ride the byte model too."""
    spec = _spec("fedcmoo", n_clients=2, local_steps=2,
                 uplink_codec="int8+ef")
    plan = api.plan(spec)
    s = plan.build().run_round()
    assert s["up_bytes"] == plan.up_bytes_per_round


# ------------------------------------------------- golden plan snapshots
def test_golden_plan_snapshots():
    """Serialized ExecutionPlan summaries for the config matrix match the
    checked-in golden file — a silent executor regression (e.g. a config
    quietly falling back to the per-client loop) fails here."""
    got = {name: api.plan(spec).summary()
           for name, spec in golden_matrix().items()}
    want = json.loads(GOLDEN.read_text())
    assert got == want, (
        "ExecutionPlan summaries drifted from tests/golden_plans.json; "
        "if the change is intentional regenerate with "
        "`PYTHONPATH=src python scripts/update_golden_plans.py` and "
        "review the diff.\n" + json.dumps(got, indent=2, sort_keys=True))
