"""xlstm-125m [ssm] — sLSTM + mLSTM blocks.  [arXiv:2405.04517]

12L d_model=768 4H (kv=4) d_ff=0 vocab=50304.  d_ff=0 per the assignment:
blocks use the xLSTM projection structure instead of a SwiGLU MLP.
Pattern: 2 mLSTM blocks then 1 sLSTM block (roughly the paper's 7:1-ish
mix at this scale), repeated 4x.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    pattern=("mlstm", "mlstm", "slstm"),
    n_periods=4,
    rope_theta=10000.0,
    mlstm_chunk=128,                # chunkwise-parallel mLSTM (EXPERIMENTS
                                    # §Perf hillclimb #1; 0 = naive recurrence)
    lora=None,                      # no attention projections to adapt; FIRM
                                    # runs full-parameter here (see DESIGN §4)
    source="arXiv:2405.04517",
    subquadratic=True,
)
