"""zamba2-1.2b [hybrid] — Mamba2 blocks + one shared attention block.

38L d_model=2048 32H (kv=32) d_ff=8192 ssm_state=64.  [arXiv:2411.15242]
Pattern: 5 Mamba2 blocks then the (single, shared-parameter) attention
block, repeated; 38 layers ~ 6 periods of (5 mamba + shared attn) + 2.
We use 6 periods of (5x mamba2 + shared_attn) + 2 extra mamba = 38 layers,
expressed as pattern len 19 x 2 periods.
"""
from repro.configs.base import ModelConfig

_PERIOD = ("mamba2",) * 5 + ("shared_attn",) + ("mamba2",) * 5 + \
    ("shared_attn",) + ("mamba2",) * 5 + ("shared_attn",) + ("mamba2",)

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    pattern=_PERIOD,             # 19 slots
    n_periods=2,                 # 38 layers
    rope_theta=10000.0,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    source="arXiv:2411.15242",
    subquadratic=True,
)
