"""mixtral-8x22b [moe] — 8 experts top-2, SWA.  [arXiv:2401.04088]

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=32768,
    pattern=("moe_swa",),
    n_periods=56,
    rope_theta=1000000.0,
    sliding_window=4096,
    moe=MoEConfig(n_experts=8, top_k=2),
    source="arXiv:2401.04088",
    subquadratic=True,
)
