"""llama-3.2-1b — the paper's own experimental model (Sec. 5).

16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256.
[hf:meta-llama/Llama-3.2-1B-Instruct]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab=128256,
    pattern=("attn",),
    n_periods=16,
    rope_theta=500000.0,
    source="hf:meta-llama/Llama-3.2-1B-Instruct",
    subquadratic=False,
)
