"""mistral-large-123b [dense].  [hf:mistralai/Mistral-Large-Instruct-2407]

88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=28672,
    vocab=32768,
    pattern=("attn",),
    n_periods=88,
    rope_theta=1000000.0,
    source="hf:mistralai/Mistral-Large-Instruct-2407",
    subquadratic=False,
)
