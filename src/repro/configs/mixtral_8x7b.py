"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.  [arXiv:2401.04088]
SWA(4096) makes decode sub-quadratic -> eligible for long_500k.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    pattern=("moe_swa",),
    n_periods=32,
    rope_theta=1000000.0,
    sliding_window=4096,
    moe=MoEConfig(n_experts=8, top_k=2),
    source="arXiv:2401.04088",
    subquadratic=True,
)
