"""llama-3.2-vision-90b [vlm] — cross-attn image layers.

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
[hf:meta-llama/Llama-3.2-11B-Vision] (90B scale-up per assignment).
Cross-attention layers are interleaved every 5th layer; the vision encoder
is a stub — ``input_specs`` supplies precomputed patch embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    pattern=("attn", "attn", "attn", "attn", "cross"),
    n_periods=20,
    rope_theta=500000.0,
    n_vision_tokens=1601,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
    subquadratic=False,
)
