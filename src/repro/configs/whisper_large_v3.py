"""whisper-large-v3 [audio] — encoder-decoder, conv frontend stubbed.

32L (decoder) d_model=1280 20H (kv=20) d_ff=5120 vocab=51866.
[arXiv:2212.04356].  ``input_specs`` provides precomputed mel/conv frame
embeddings; the 32-layer encoder + 32-layer decoder transformer is real.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    pattern=("cross",),          # decoder blocks: self + cross to encoder
    n_periods=32,
    rope_theta=10000.0,
    encoder_layers=32,
    encoder_len_ratio=1,
    decoder_len_ratio=4,
    is_encoder_decoder=True,
    source="arXiv:2212.04356",
    subquadratic=False,
)
