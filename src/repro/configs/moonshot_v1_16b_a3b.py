"""moonshot-v1-16b-a3b [dense/MoE] — kimi/moonlight MoE 64e top-6.

48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840.
[hf:moonshotai/Moonlight-16B-A3B]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="dense",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=163840,
    pattern=("moe",),
    n_periods=48,
    rope_theta=50000.0,
    moe=MoEConfig(n_experts=64, top_k=6),
    source="hf:moonshotai/Moonlight-16B-A3B",
    subquadratic=False,
)
