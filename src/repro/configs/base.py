"""Model / run configuration dataclasses.

A ``ModelConfig`` fully describes one architecture from the assigned pool.
The layer stack is expressed as a *periodic pattern*: ``pattern`` is the
tuple of block kinds inside one period and ``n_periods`` repeats it, so
``n_layers == len(pattern) * n_periods``.  The forward pass scans over
periods (O(1)-depth HLO) and unrolls the (short) pattern inside the scan
body.  Block kinds:

  'attn'        full-causal GQA self-attention + SwiGLU MLP
  'swa'         sliding-window GQA self-attention + MLP (or MoE if moe set)
  'moe'         full-causal GQA self-attention + MoE FFN
  'moe_swa'     sliding-window GQA + MoE FFN
  'cross'       GQA self-attention + cross-attention (to vision/encoder
                embeddings) + MLP          (VLM / decoder blocks)
  'mamba2'      Mamba2 SSD block
  'shared_attn' attention block with ONE shared parameter set reused every
                period (zamba2-style)
  'mlstm'       xLSTM matrix-memory (linear-attention) block
  'slstm'       xLSTM scalar-memory recurrent block
  'enc_attn'    bidirectional encoder attention + MLP (whisper encoder)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class LoRAConfig:
    rank: int = 16
    alpha: float = 32.0
    # projection names inside attention blocks that receive adapters
    targets: Tuple[str, ...] = ("wq", "wk", "wv", "wo")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | vlm | audio | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    # layer program -----------------------------------------------------
    pattern: Tuple[str, ...] = ("attn",)
    n_periods: int = 0               # 0 -> n_layers / len(pattern)
    # attention ----------------------------------------------------------
    head_dim: int = 0                # 0 -> d_model // n_heads
    rope_theta: float = 500000.0
    sliding_window: int = 0          # 0 -> full attention
    # extras ---------------------------------------------------------------
    moe: Optional[MoEConfig] = None
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128             # SSD chunk length (perf knob)
    conv_dim: int = 4                # mamba conv kernel width
    # vlm / enc-dec --------------------------------------------------------
    n_vision_tokens: int = 0         # VLM stub patch-embedding count
    encoder_layers: int = 0          # whisper encoder depth
    encoder_len_ratio: int = 1       # enc frames = seq // ratio at train
    decoder_len_ratio: int = 1       # dec tokens = seq // ratio at train
    # adapters / training --------------------------------------------------
    lora: Optional[LoRAConfig] = LoRAConfig()
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    remat: bool = True               # jax.checkpoint around each period
    remat_policy: str = "full"       # full | dots (save MXU outputs)
    attn_block: int = 512            # chunked-attention KV block size
    mlstm_chunk: int = 0             # 0 = exact recurrence; >0 = chunkwise
    batched_vjp: bool = True         # vmap the M cotangent pulls (§Perf:
                                     # shares one remat forward across M)
    tensor_parallel: bool = True     # shard weights on 'model' (off = pure
                                     # DP; right call for sub-1B models)
    # provenance -----------------------------------------------------------
    source: str = ""
    # capability flags -------------------------------------------------------
    subquadratic: bool = False       # eligible for long_500k
    is_encoder_decoder: bool = False

    # ------------------------------------------------------------------ derived
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_periods == 0:
            object.__setattr__(
                self, "n_periods", max(1, self.n_layers // len(self.pattern)))

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def reduced(self, n_layers: int = 2, d_model: int = 256,
                vocab: int = 512) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests."""
        pat = self.pattern
        n_per = max(1, n_layers // len(pat))
        n_heads = max(2, min(4, self.n_heads))
        n_kv = max(1, min(n_heads, self.n_kv_heads))
        if n_heads % n_kv:
            n_kv = 1
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(self.moe,
                                      n_experts=min(4, self.moe.n_experts),
                                      top_k=min(2, self.moe.top_k))
        return dataclasses.replace(
            self, name=self.name + "-smoke", n_layers=n_per * len(pat),
            n_periods=n_per, d_model=d_model, n_heads=n_heads,
            n_kv_heads=n_kv, head_dim=d_model // n_heads,
            d_ff=2 * d_model, vocab=vocab, moe=moe,
            ssm_state=min(16, self.ssm_state) if self.ssm_state else 0,
            n_vision_tokens=min(16, self.n_vision_tokens),
            encoder_layers=min(2, self.encoder_layers),
            sliding_window=min(128, self.sliding_window)
            if self.sliding_window else 0,
        )

    # parameter count (analytic, for roofline MODEL_FLOPS) ----------------
    def param_count(self, active_only: bool = False) -> int:
        d, dff, hd = self.d_model, self.d_ff, self.head_dim
        per = {}
        q = self.n_heads * hd
        kv = self.n_kv_heads * hd
        attn = d * q + 2 * d * kv + q * d
        mlp = 3 * d * dff
        if self.moe is not None:
            n_e = self.moe.top_k if active_only else self.moe.n_experts
            moe_mlp = 3 * d * dff * n_e + d * self.moe.n_experts
        else:
            moe_mlp = mlp
        din = self.ssm_expand * d
        nh_ssm = max(1, din // self.ssm_head_dim) if self.ssm_state else 0
        mamba = (d * (2 * din + 2 * self.ssm_state + nh_ssm)  # in_proj
                 + self.conv_dim * (din + 2 * self.ssm_state)
                 + din * d + nh_ssm * 2)                       # out_proj, A, D
        per["attn"] = attn + mlp + 2 * d
        per["enc_attn"] = per["attn"]
        per["swa"] = per["attn"]
        per["moe"] = attn + moe_mlp + 2 * d
        per["moe_swa"] = per["moe"]
        per["cross"] = attn + (d * q + 2 * d * kv + q * d) + mlp + 3 * d
        per["mamba2"] = mamba + d
        per["shared_attn"] = attn + mlp + 2 * d
        per["mlstm"] = (d * 3 * q + q * d + 2 * d * dff if dff else
                        d * 3 * q + q * d + 3 * self.n_heads * hd) + d
        per["slstm"] = 4 * (d * d + d * d + 2 * d) + d
        total = 0
        seen_shared = False
        for kind in self.pattern:
            n = 1 if kind == "shared_attn" and seen_shared else self.n_periods
            if kind == "shared_attn":
                n = 1  # one parameter set total
                seen_shared = True
            total += per[kind] * n
        total += self.vocab * d              # embed
        if not self.tie_embeddings:
            total += self.vocab * d          # lm head
        total += d                           # final norm
        if self.encoder_layers:
            total += self.encoder_layers * per["enc_attn"]
        return int(total)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class FIRMConfig:
    """Hyper-parameters of the paper's algorithm (Alg. 1 + App. A)."""
    n_objectives: int = 2
    n_clients: int = 8
    rounds: int = 16
    local_steps: int = 3             # K
    batch_size: int = 16             # B prompts per local step
    beta: float = 0.01               # MGDA regularization (T2)
    preference: Optional[Tuple[float, ...]] = None   # p vector (Eq. 3)
    # beyond-paper extensions (paper §6 future work) -----------------------
    participation: float = 1.0       # fraction of clients sampled per round
    client_preferences: Optional[Tuple[Tuple[float, ...], ...]] = None
    # per-client p vectors (pluralistic alignment); overrides `preference`
    client_local_steps: Optional[Tuple[int, ...]] = None
    # per-client K (FedMOA-style heterogeneous compute rates); clients with
    # equal K form one vmapped cohort in the group-by-config dispatch
    lambda_smoothing: bool = True    # eta_t smoothing (Alg. 2, Eq. 12)
    eta0: float = 1.0
    actor_lr: float = 6e-5
    critic_lr: float = 1e-4
    ppo_clip: float = 0.2
    kl_target: float = 0.03
    kl_coef_init: float = 0.1
    gamma: float = 0.99
    gae_lambda: float = 0.95
    trace_normalize: bool = True     # App. A Gram normalisation
    solver: str = "pgd"              # pgd | closed_form_m2 | frank_wolfe
    solver_iters: int = 100


@dataclasses.dataclass(frozen=True)
class SchedConfig:
    """Scheduler-subsystem knobs (repro.fed.sched).

    ``policy`` selects the aggregation discipline; ``profile`` names a
    heterogeneity preset from repro.fed.sched.profiles.  The deadline
    policy over-selects by ``overselect`` and drops participants whose
    *predicted* round time (analytic codec bytes + profile rates) exceeds
    the deadline — absolute seconds, or the ``deadline_quantile`` of the
    selected cohort's predicted times when set.  The fedbuff policy
    aggregates every ``buffer_size`` arrivals with staleness weights
    w ∝ (1+s)^-staleness_pow and scales FIRM's β by the client's observed
    staleness bucket (core.firm.staleness_beta).
    """
    policy: str = "sync"             # sync | deadline | fedbuff
    profile: str = "homogeneous"     # profiles preset name
    profile_seed: int = 0
    # deadline policy
    overselect: float = 1.0          # select overselect * (p * C) clients
    deadline_s: float = float("inf")
    deadline_quantile: Optional[float] = None
    # fedbuff policy
    buffer_size: int = 0             # aggregate every B arrivals; 0 -> C
    staleness_pow: float = 0.5
    staleness_beta_gain: float = 0.0
    staleness_beta_cap: float = 8.0
    staleness_bucket_max: int = 3    # β buckets bound retraces/compiles


# Deployment-profile codec presets (repro.comms registry specs) — the
# (uplink, downlink) pairs the codec_tradeoff benchmark and examples sweep.
# Uplink is the scarce direction for cross-device FL, hence the asymmetry.
CODEC_PRESETS = {
    "datacenter": ("identity", "identity"),      # measured baseline
    "wan": ("int8+ef", "identity"),              # ~4x uplink reduction
    "mobile": ("int4+ef", "int8"),               # both directions coded
    "extreme": ("topk:0.05+ef", "int8"),         # ~10x uplink reduction
    "powersgd": ("lowrank:4+ef", "identity"),    # rank-r sketch uplink
}
