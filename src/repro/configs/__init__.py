"""Architecture registry: ``get_config(arch_id)`` / ``list_archs()``."""
from __future__ import annotations

import importlib

from repro.configs.base import (FIRMConfig, InputShape, INPUT_SHAPES,
                                LoRAConfig, MoEConfig, ModelConfig)

_ARCH_MODULES = {
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "glm4-9b": "glm4_9b",
    "mixtral-8x7b": "mixtral_8x7b",
    "whisper-large-v3": "whisper_large_v3",
    "zamba2-1.2b": "zamba2_1_2b",
    "mistral-large-123b": "mistral_large_123b",
    "mixtral-8x22b": "mixtral_8x22b",
    "xlstm-125m": "xlstm_125m",
    # the paper's own model
    "llama-3.2-1b": "llama32_1b",
}

ASSIGNED_ARCHS = tuple(k for k in _ARCH_MODULES if k != "llama-3.2-1b")


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


def list_archs():
    return list(_ARCH_MODULES)


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]


__all__ = ["ModelConfig", "MoEConfig", "LoRAConfig", "FIRMConfig",
           "InputShape", "INPUT_SHAPES", "ASSIGNED_ARCHS",
           "get_config", "get_shape", "list_archs"]
