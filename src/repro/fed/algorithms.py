"""Algorithm protocol + registry: capability-driven federated algorithms.

Every federated algorithm the engine can run — the paper's FIRM, its
β = 0 ablation, linear scalarization, and the server-centric FedCMOO
baseline — is a first-class ``Algorithm`` object owning three things:

* its **local-step machinery**: the jitted per-client loop step
  (``local_step_fn``), the traced step the vectorized/fused round body
  vmaps (``traced_step``), and — for algorithms with a host-driven
  server exchange — the whole exchange phase
  (``loop_phase`` / ``exchange_phase_vectorized``);
* its **config resolution**: ``resolve_config`` (e.g. firm_unreg pins
  β = 0 so it shares firm's trace), ``validate`` (e.g. fedcmoo rejects
  heterogeneous per-client local-step counts), and the per-client
  config expansion (``client_configs``);
* its declared **capabilities** (``Capabilities``) — the ONLY thing the
  engine and the ``repro.fed.api`` planner dispatch on.  The engine
  never branches on algorithm-name strings; adding an algorithm is one
  ``register_algorithm`` call, after which every executor decision
  (loop / cohort-vectorized / fused) falls out of the capability
  queries.

Capability semantics
--------------------
``vmap_safe``
    The per-client local step can ride ``jax.vmap`` over a stacked
    client axis (one program per cohort).  False forces the per-client
    Python loop.
``traced_server_exchange``
    Any server interaction the algorithm performs DURING the local
    phase stays inside the traced program.  Client-local algorithms
    (firm/linear — no mid-phase exchange at all) are trivially True;
    fedcmoo's per-step λ solve runs on the host between two jitted
    phases, so it is False.  False also routes the vectorized local
    phase through ``exchange_phase_vectorized`` instead of the shared
    scanned round body.
``single_cohort_required``
    Every participant must advance in lock-step through one dispatch
    group (fedcmoo's λ is global per local step).  With several static
    cohorts such an algorithm falls back to the loop, and the async
    scheduler policies reject it.
``fusable``
    Eligible for the round-level ``lax.scan`` (``fused_rounds``).
    Requires ``traced_server_exchange`` and ``vmap_safe`` —
    ``register_algorithm`` rejects a declaration that violates either
    (the scan body cannot leave the graph).
"""
from __future__ import annotations

import dataclasses
import functools
from functools import partial
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.comms import ErrorFeedback
from repro.configs.base import FIRMConfig
from repro.core import fedcmoo
from repro.data.partition import sample_prompt_block
from repro.models import transformer
from repro.models.common import merge_trainable
from repro.rlhf import local as local_lib
from repro.rlhf import ppo, rewards as rewards_lib
from repro.rlhf.sampling import generate


@dataclasses.dataclass(frozen=True)
class Capabilities:
    """What an algorithm's execution paths can do (see module docstring)."""
    vmap_safe: bool = True
    traced_server_exchange: bool = True
    single_cohort_required: bool = False
    fusable: bool = True


def validate_capabilities(caps: Capabilities, name: str) -> None:
    """Reject internally inconsistent capability declarations."""
    if caps.fusable and not caps.traced_server_exchange:
        raise ValueError(
            f"algorithm {name!r} declares fusable=True but "
            "traced_server_exchange=False: the round-level lax.scan "
            "cannot pause for a host-driven server exchange")
    if caps.fusable and not caps.vmap_safe:
        raise ValueError(
            f"algorithm {name!r} declares fusable=True but "
            "vmap_safe=False: the fused round body vmaps the local step "
            "over the stacked client axis")


# Jitted callables are memoized on the (hashable, frozen) configs so every
# trainer with the same architecture + FIRM hyperparameters shares one
# trace/compile per process.
@functools.lru_cache(maxsize=None)
def _jit_local_step(cfg, cfc: FIRMConfig):
    # the client-state argument is donated: its buffers are reused for the
    # updated state in place.  Callers must pass states whose buffers are
    # not aliased elsewhere (the engine adopts the broadcast by copy).
    return jax.jit(partial(local_lib.firm_local_step, cfg, cfc),
                   donate_argnums=(0,))


@functools.lru_cache(maxsize=None)
def _jit_sample_block(batch_size: int, prompt_len: int, vocab: int):
    return jax.jit(lambda seeds, counts, probs: sample_prompt_block(
        seeds, counts, probs, batch_size, prompt_len, vocab))


class Algorithm:
    """Base protocol; subclasses fill in the hooks their capabilities
    promise.

    ``traced_server_exchange=True`` algorithms implement ``traced_step``
    (used by the shared vectorized/fused round body) and ``loop_phase``;
    ``traced_server_exchange=False`` algorithms implement ``loop_phase``
    and ``exchange_phase_vectorized`` instead.  ``kernel`` is the
    trace-cache key for the shared round body: algorithms that lower to
    the same traced step (firm / firm_unreg after ``resolve_config``)
    share one compile by sharing a kernel name.
    """

    name: str = "algorithm"
    kernel: str = "algorithm"
    caps: Capabilities = Capabilities()
    # plan-time dispatch-cost model: engine-counted jit dispatches per
    # client-step on the per-client loop path
    loop_dispatches_per_client_step: int = 3

    # ---- config resolution -------------------------------------------
    def validate(self, fc: FIRMConfig, ec) -> None:
        """Raise if (fc, ec) cannot run under this algorithm."""

    def resolve_config(self, fc: FIRMConfig) -> FIRMConfig:
        """The FIRMConfig the local step actually traces against."""
        return fc

    # ---- local-step machinery ----------------------------------------
    def local_step_fn(self, cfg, cfc: FIRMConfig):
        """Jitted per-client loop step, or None if the loop phase builds
        its own dispatches."""
        return None

    def traced_step(self, cfg, cfc: FIRMConfig, st, frozen, batch, pref,
                    extra):
        """One client's local update inside the traced round body."""
        raise NotImplementedError(self.name)

    def traced_extra(self, cfc: FIRMConfig, ec):
        """Static-per-run operand threaded to ``traced_step`` (e.g. the
        linear scalarization weights); None when unused."""
        return None

    def loop_phase(self, tr, fc: FIRMConfig, participants: List[int]
                   ) -> List[dict]:
        """Per-client-loop local phase; returns per-entry metric dicts
        (each with 'client', 'rewards', 'kl' and, when the algorithm
        produces one, 'lam')."""
        raise NotImplementedError(self.name)

    def exchange_phase_vectorized(self, tr, cfc: FIRMConfig,
                                  participants: List[int], stacked, seeds,
                                  counts0, probs, band_h, band_x):
        """Vectorized local phase for host-exchange algorithms; returns
        (lams, rewards_mean, kl_mean, rewards_pc, stacked)."""
        raise NotImplementedError(self.name)

    # ---- plan-time cost model ----------------------------------------
    def vec_phase_dispatches(self, k_steps: int) -> int:
        """Engine-counted dispatches inside one cohort's vectorized
        local phase (excluding the stack/unstack pair)."""
        return 1

    def uplink_bytes_per_participant(self, fc: FIRMConfig, ul_codec,
                                     d: int) -> int:
        """Exact wire bytes one participant uploads per round."""
        return ul_codec.nbytes_static(d)

    # ------------------------------------------------------------------
    def __repr__(self) -> str:
        return f"<Algorithm {self.name} caps={self.caps}>"


def _step_major(tr, participants: List[int]):
    """The canonical loop order: step-major over participants with
    per-client K (heterogeneous ``client_local_steps`` finish early and
    skip) — the order the cohort path's pre-drawn generation keys
    replicate."""
    steps = {c: tr._client_fcs[c].local_steps for c in participants}
    for k in range(max(steps.values())):
        for c in participants:
            if k < steps[c]:
                yield c


class FIRMAlgorithm(Algorithm):
    """Paper Alg. 1: in-client regularized MGDA (client-local)."""

    name = "firm"
    kernel = "firm"
    caps = Capabilities()
    loop_dispatches_per_client_step = 3     # generate, ref logprobs, step

    def local_step_fn(self, cfg, cfc: FIRMConfig):
        return _jit_local_step(cfg, cfc)

    def traced_step(self, cfg, cfc, st, frozen, batch, pref, extra):
        return local_lib.firm_local_step(cfg, cfc, st, frozen, batch,
                                         preference=pref)

    def loop_phase(self, tr, fc, participants):
        metrics = []
        for c in _step_major(tr, participants):
            batch = tr._make_batch(c)
            tr.client_states[c], m = tr._jit_steps[c](
                tr.client_states[c], tr.frozen, batch)
            tr.jit_dispatches += 1
            m["client"] = c
            metrics.append(m)
        return metrics


class FIRMUnregAlgorithm(FIRMAlgorithm):
    """β = 0 ablation (RQ2): identical machinery, regularizer off.

    ``kernel`` stays "firm" — after ``resolve_config`` pins β = 0 the
    traced step is the same program, so firm and firm_unreg share every
    trace cache.
    """

    name = "firm_unreg"

    def resolve_config(self, fc):
        return dataclasses.replace(fc, beta=0.0)


class LinearAlgorithm(Algorithm):
    """Fixed-weight linear scalarization (implicit baseline)."""

    name = "linear"
    kernel = "linear"
    caps = Capabilities()
    loop_dispatches_per_client_step = 2     # generate, ref logprobs

    def traced_step(self, cfg, cfc, st, frozen, batch, pref, extra):
        return local_lib.linear_local_step(cfg, cfc, st, frozen, batch,
                                           extra)

    def traced_extra(self, cfc, ec):
        return jnp.asarray(
            ec.linear_weights
            or [1.0 / cfc.n_objectives] * cfc.n_objectives, jnp.float32)

    def loop_phase(self, tr, fc, participants):
        w = self.traced_extra(fc, tr.ec)
        metrics = []
        for c in _step_major(tr, participants):
            batch = tr._make_batch(c)
            grads, losses, extras = local_lib.fedcmoo_local_grads(
                tr.cfg, fc, tr.client_states[c], tr.frozen, batch)
            tr.client_states[c], m = local_lib.fedcmoo_local_apply(
                fc, tr.client_states[c], grads, w, extras)
            m["client"] = c
            m["rewards"] = batch.rewards.mean(0)
            metrics.append(m)
        return metrics


@functools.lru_cache(maxsize=None)
def _jit_vec_fedcmoo_grads(cfg, cfc: FIRMConfig, max_new: int,
                           length_tol: int):
    """FedCMOO client phase 1, vmapped: rollouts + M gradients for every
    participant in one dispatch.  Gradients return stacked so the server
    exchange (per-client codec Payloads + one λ solve) stays at the host
    boundary between the two jitted phases."""
    m = cfc.n_objectives

    def fn(state, frozen, ref_params, prompts, keys, band_h, band_x):
        def one(st, pr, key, bh, bx):
            params = merge_trainable(st.trainable, frozen)
            tokens, old_lp, mask = generate(cfg, params, pr, key,
                                            max_new=max_new)
            r = rewards_lib.score_batch_banded(bh, bx, tokens, mask, m,
                                               length_tol)
            ref_out = transformer.forward_seq(cfg, ref_params, tokens)
            ref_lp = ppo.token_logprobs(ref_out["logits"], tokens)
            batch = ppo.PPOBatch(tokens, mask, old_lp, ref_lp, r)
            grads, losses, extras = local_lib.fedcmoo_local_grads(
                cfg, cfc, st, frozen, batch)
            return grads, extras, batch.rewards.mean(0)

        return jax.vmap(one)(state, prompts, keys, band_h, band_x)

    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def _jit_vec_fedcmoo_apply(cfc: FIRMConfig):
    """FedCMOO client phase 2, vmapped, with the stacked state donated."""

    def fn(state, grads, lam, extras):
        def one(st, g, e):
            return local_lib.fedcmoo_local_apply(cfc, st, g, lam, e)

        return jax.vmap(one)(state, grads, extras)

    return jax.jit(fn, donate_argnums=(0,))


@functools.lru_cache(maxsize=None)
def _jit_grads_flat(m: int):
    return jax.jit(partial(fedcmoo.stack_grads_flat, m=m))


class FedCMOOAlgorithm(Algorithm):
    """Server-centric MGDA baseline (RQ1, Askin et al. 2024).

    Gradients go up every local step and the server broadcasts one
    global λ back — a HOST-driven exchange between two jitted phases,
    hence ``traced_server_exchange=False`` (never fused) and
    ``single_cohort_required=True`` (λ is global per step, so every
    participant must advance in lock-step).
    """

    name = "fedcmoo"
    kernel = "fedcmoo"
    caps = Capabilities(vmap_safe=True, traced_server_exchange=False,
                        single_cohort_required=True, fusable=False)
    loop_dispatches_per_client_step = 2     # generate, ref logprobs

    def validate(self, fc, ec):
        if fc.client_local_steps is not None:
            raise ValueError("fedcmoo needs homogeneous local_steps: its "
                             "server λ exchange is global per local step")

    def vec_phase_dispatches(self, k_steps: int) -> int:
        # per step: sampler, vmapped grads, batched flatten, vmapped apply
        return 4 * k_steps

    def uplink_bytes_per_participant(self, fc, ul_codec, d):
        # M per-step gradient uploads ride the EF-stripped inner codec on
        # top of the end-of-round adapted-param delta
        grad = self._grad_codec(ul_codec)
        return (ul_codec.nbytes_static(d)
                + fc.n_objectives * fc.local_steps * grad.nbytes_static(d))

    @staticmethod
    def _grad_codec(ul_codec):
        """Codec for per-step gradient uploads: error feedback is defined
        per client *stream*, not per objective, so the M parallel
        gradient trees use the EF-stripped inner codec."""
        return ul_codec.inner if isinstance(ul_codec, ErrorFeedback) \
            else ul_codec

    def loop_phase(self, tr, fc, participants):
        grad_codec = self._grad_codec(tr.uplink_codec)
        metrics = []
        for k in range(fc.local_steps):
            per_client = []
            server_grads = []
            for c in participants:
                batch = tr._make_batch(c)
                grads, losses, extras = local_lib.fedcmoo_local_grads(
                    tr.cfg, fc, tr.client_states[c], tr.frozen, batch)
                per_client.append((grads, extras, batch.rewards.mean(0)))
                # gradients go up every local step: the O(CMd) cost; the
                # server solves λ from what it actually receives (codec
                # error feeds the q-term, Askin et al. Rmk 4.6)
                received = []
                for g in grads:
                    gp, _, dec = grad_codec.roundtrip(g, key=tr._next_key())
                    tr.ledger.send_up(gp)
                    received.append(dec)
                server_grads.append(received)
            lam = fedcmoo.fedcmoo_round_lambda(
                server_grads, compress_rank=tr.ec.fedcmoo_compress_rank,
                key=tr._next_key())
            for ci, c in enumerate(participants):
                grads, extras, rmean = per_client[ci]
                tr.client_states[c], m = local_lib.fedcmoo_local_apply(
                    fc, tr.client_states[c], grads, lam, extras)
                m["client"] = c
                m["rewards"] = rmean
                metrics.append(m)
        return metrics

    def exchange_phase_vectorized(self, tr, cfc, participants, stacked,
                                  seeds, counts0, probs, band_h, band_x):
        """Two jitted dispatches per step (vmapped grads, vmapped apply)
        around the batched server exchange: all C×M gradient trees
        flatten in one batched tree op, the codec encodes them at the
        stacked Payload boundary (one kernel dispatch for quantize
        codecs), and the stacked decode feeds the λ solve directly — no
        per-client host loop."""
        m = cfc.n_objectives
        p_count = len(participants)
        grad_codec = self._grad_codec(tr.uplink_codec)
        grads_fn = _jit_vec_fedcmoo_grads(tr.cfg, cfc, tr.ec.max_new,
                                          tr._length_tol)
        apply_fn = _jit_vec_fedcmoo_apply(cfc)
        sampler = _jit_sample_block(cfc.batch_size, tr.ec.prompt_len,
                                    tr.cfg.vocab)
        lam_last, rew_hist, kl_hist = None, [], []
        for k in range(cfc.local_steps):
            # key parity with the loop path: per client, one batch key
            # then M gradient-codec keys, interleaved in participant order
            kb, kg = [], []
            for _ in participants:
                kb.append(tr._next_key())
                kg.extend(tr._next_key() for _ in range(m))
            prompts = sampler(seeds, counts0 + k, probs)
            tr.jit_dispatches += 1
            grads, extras, rmean = grads_fn(
                stacked, tr.frozen, tr.ref_params, prompts,
                jnp.stack(kb), band_h, band_x)
            tr.jit_dispatches += 1
            # (C, M, d) client-major rows match the loop path's upload
            # order, so payload keys and ledger bytes are identical
            gmat = _jit_grads_flat(m)(grads)
            tr.jit_dispatches += 1
            gpayloads, _, gdec = grad_codec.roundtrip_stacked(
                gmat.reshape(p_count * m, -1), tr._delta_spec, keys=kg)
            for gp in gpayloads:
                tr.ledger.send_up(gp)
            lam = fedcmoo.fedcmoo_round_lambda_stacked(
                gdec.reshape(p_count, m, -1),
                compress_rank=tr.ec.fedcmoo_compress_rank,
                key=tr._next_key())
            stacked, metrics = apply_fn(stacked, grads, lam, extras)
            tr.jit_dispatches += 1
            lam_last = metrics["lam"]
            rew_hist.append(rmean)
            kl_hist.append(metrics["kl"])
        rewards_mean = jnp.stack(rew_hist).reshape(-1, m).mean(0)
        kl_mean = jnp.stack(kl_hist).mean()
        rewards_pc = jnp.stack(rew_hist).mean(0)              # (C, M)
        return lam_last, rewards_mean, kl_mean, rewards_pc, stacked


# ---------------------------------------------------------------- registry
_REGISTRY: Dict[str, Algorithm] = {}


def register_algorithm(algorithm: Algorithm) -> Algorithm:
    """Validate the capability declaration and add the algorithm to the
    registry (name collisions overwrite — latest wins, like codecs)."""
    validate_capabilities(algorithm.caps, algorithm.name)
    _REGISTRY[algorithm.name] = algorithm
    return algorithm


def get_algorithm(name: str) -> Algorithm:
    if name not in _REGISTRY:
        raise ValueError(f"unknown algorithm {name!r}; "
                         f"available: {available_algorithms()}")
    return _REGISTRY[name]


def available_algorithms() -> tuple:
    return tuple(sorted(_REGISTRY))


register_algorithm(FIRMAlgorithm())
register_algorithm(FIRMUnregAlgorithm())
register_algorithm(LinearAlgorithm())
register_algorithm(FedCMOOAlgorithm())


def client_configs(algorithm: Algorithm, fc: FIRMConfig
                   ) -> List[FIRMConfig]:
    """Per-client FIRM configs (pluralistic preferences §6 future work,
    FedMOA-style heterogeneous local-step rates), expanded from the
    algorithm-resolved base config.  Single source of truth for the
    trainer AND the plan-time cohort structure."""
    base = algorithm.resolve_config(fc)
    out = []
    for c in range(fc.n_clients):
        cfc = base
        if fc.client_preferences is not None:
            cfc = dataclasses.replace(
                cfc, preference=fc.client_preferences[c])
        if fc.client_local_steps is not None:
            cfc = dataclasses.replace(
                cfc, local_steps=int(fc.client_local_steps[c]))
        out.append(cfc)
    return out
