"""Declarative run API: RunSpec -> plan() -> ExecutionPlan -> execute().

The front door to the federated engine.  A ``RunSpec`` names WHAT to run
(model x FIRM hyperparameters x engine knobs x optional scheduler);
``plan()`` resolves algorithm x codec x scheduler-policy x cohort
structure into an inspectable ``ExecutionPlan`` — chosen executor
(``loop`` / ``vectorized`` / ``fused``), fused chunking, cohort plan,
predicted per-round jit dispatches, and exact predicted wire bytes
(from the codecs' ``nbytes_static``) — all BEFORE any parameter is
initialized or any program compiled.  ``execute(plan)`` (or
``plan.execute()``) builds the trainer and runs it.

    spec = RunSpec(model=cfg, firm=fc, engine=EngineConfig(fused_rounds=8))
    p = plan(spec)
    p.executor            # "fused"
    p.up_bytes_per_round  # exact wire bytes, no compilation happened
    history = execute(p)

Every executor decision is a CAPABILITY query against the Algorithm
registry (``repro.fed.algorithms``) — the planner and the engine share
``resolve_local_mode`` / ``resolve_fused``, so the plan is guaranteed to
reproduce what the engine actually does, and the engine itself never
branches on algorithm-name strings.  ``ExecutionPlan.summary()`` is
JSON-able; ``tests/test_plan.py`` diffs a config matrix of summaries
against a checked-in golden file so a config silently falling back to
the per-client loop fails PRs.
"""
from __future__ import annotations

import dataclasses
import functools
from functools import partial
from typing import List, Optional, Sequence, Tuple

import jax

from repro.configs.base import FIRMConfig, ModelConfig, SchedConfig
from repro.comms import make_codec
from repro.fed.algorithms import (Algorithm, Capabilities, client_configs,
                                  get_algorithm)
from repro.fed.sched.cohort import build_cohorts, cohort_summaries


@dataclasses.dataclass
class EngineConfig:
    """Engine knobs orthogonal to the FIRM hyperparameters.

    ``algorithm`` names a registry entry (``repro.fed.algorithms``);
    everything execution-path related (vectorized_clients/fused_rounds)
    is a REQUEST the planner grants only when the algorithm's declared
    capabilities and the codec contracts allow it — see ``plan()``.
    """
    algorithm: str = "firm"
    prompt_len: int = 8
    max_new: int = 24
    dirichlet_alpha: float = 0.3
    seed: int = 0
    heterogeneous_rms: bool = False      # half the clients use the alt RM
    fedcmoo_compress_rank: Optional[int] = None   # fedcmoo sketch rank
    linear_weights: Optional[Sequence[float]] = None  # linear scalarization
    # comms codecs (repro.comms registry specs, e.g. "int8+ef")
    uplink_codec: str = "identity"       # client -> server deltas/grads
    downlink_codec: str = "identity"     # server -> client broadcast
    # run the round's local phase as one vmapped/scanned jit over the
    # stacked client axis (falls back per the capability rules in plan())
    vectorized_clients: bool = True
    # fuse R federated rounds into ONE jitted program (round-level
    # lax.scan with the traced codec contract): 1 = per-round dispatch;
    # >1 amortizes Python dispatch and the per-round host transfer over
    # R rounds.  Granted only for fusable algorithms on the
    # single-cohort vectorized path with traceable codecs.
    fused_rounds: int = 1
    # extra telemetry sinks for the round-summary pipeline
    # (repro.obs.metrics specs, e.g. "jsonl:metrics.jsonl" or
    # "jsonl:m.jsonl,csv:m.csv"); an in-memory sink is always attached
    metrics_sink: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """Everything needed to plan and run one federated training job."""
    model: ModelConfig
    firm: FIRMConfig
    engine: EngineConfig = dataclasses.field(default_factory=EngineConfig)
    sched: Optional[SchedConfig] = None   # None -> bare engine (no clock)
    rounds: Optional[int] = None          # None -> firm.rounds


# ------------------------------------------------------- shared resolution
def resolve_local_mode(algorithm: Algorithm,
                       client_fcs: Sequence[FIRMConfig],
                       participants: Sequence[int], *,
                       vectorized_clients: bool,
                       lift_preference: bool):
    """One round's local-phase path from capability queries alone.

    Returns ``(mode, cohort_plan, reason)`` with mode one of ``"vec"``
    (single vmapped cohort), ``"cohort"`` (one vmapped dispatch per
    static-config group) or ``"loop"`` (per-client Python loop).  Shared
    verbatim by the engine (per round, actual participants) and the
    planner (full population), so plans cannot drift from execution.
    """
    if not vectorized_clients:
        return "loop", None, "vectorized_clients disabled by config"
    if not algorithm.caps.vmap_safe:
        return "loop", None, (f"{algorithm.name}: local step is not "
                              "vmap-safe")
    has = [client_fcs[c].preference is not None for c in participants]
    if any(has) and not all(has):
        return "loop", None, "mixed static/absent per-client preference"
    plan = build_cohorts([(c, client_fcs[c]) for c in participants],
                         lift_preference=lift_preference)
    if len(plan) == 1:
        return "vec", plan, "single static-config cohort"
    if algorithm.caps.single_cohort_required:
        return "loop", None, (
            f"{algorithm.name} requires a single cohort (lock-step "
            f"server exchange) but static configs diverge into "
            f"{len(plan)} groups")
    return "cohort", plan, f"{len(plan)} static-config cohorts"


def resolve_fused(algorithm: Algorithm, local_mode: str, uplink_codec,
                  downlink_codec):
    """May whole rounds ride the round-level ``lax.scan``?  Returns
    ``(ok, reason)``; like ``resolve_local_mode`` this is shared by the
    engine's ``_fused_mode`` probe and the planner."""
    if not algorithm.caps.fusable:
        return False, (f"{algorithm.name} is not fusable (its server "
                       "exchange is host-driven)")
    if local_mode != "vec":
        return False, ("fused rounds need the single-cohort vectorized "
                       f"path (local mode is {local_mode!r})")
    if not (getattr(uplink_codec, "traceable", False)
            and getattr(downlink_codec, "traceable", False)):
        return False, "codec does not support the traced contract"
    return True, ("single-cohort vectorized round body stages into the "
                  "round-level scan")


@functools.lru_cache(maxsize=None)
def trainable_size(cfg: ModelConfig) -> int:
    """d = number of trainable parameters, WITHOUT materializing them.

    ``jax.eval_shape`` traces ``init_params`` to shape structs only, so
    the planner can predict exact wire bytes before any allocation or
    compilation."""
    from repro.models import transformer
    from repro.models.common import split_trainable, tree_size
    shapes = jax.eval_shape(partial(transformer.init_params, cfg),
                            jax.random.PRNGKey(0))
    trainable, _ = split_trainable(shapes)
    return int(tree_size(trainable))


# ------------------------------------------------------------ the plan
@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """The resolved execution strategy for one RunSpec — inspectable
    before compilation, executable via ``execute()``."""
    spec: RunSpec
    algorithm: str
    capabilities: Capabilities
    policy: str                           # sync | deadline | fedbuff
    executor: str                         # loop | vectorized | fused
    local_mode: str                       # loop | vec | cohort
    cohorts: Tuple[Tuple[int, int], ...]  # (n_members, local_steps) each
    n_clients: int
    participants_per_round: int
    rounds: int
    fused_chunks: Tuple[int, ...]         # () unless executor == "fused"
    dispatches_per_round: float
    d_trainable: int
    up_bytes_per_round: int
    down_bytes_per_round: int
    reasons: Tuple[str, ...]

    def summary(self) -> dict:
        """JSON-able snapshot (the golden-plan test diffs these)."""
        ec = self.spec.engine
        return {
            "algorithm": self.algorithm,
            "capabilities": dataclasses.asdict(self.capabilities),
            "policy": self.policy,
            "executor": self.executor,
            "local_mode": self.local_mode,
            "cohorts": [list(c) for c in self.cohorts],
            "n_clients": self.n_clients,
            "participants_per_round": self.participants_per_round,
            "rounds": self.rounds,
            "fused_chunks": list(self.fused_chunks),
            "dispatches_per_round": round(self.dispatches_per_round, 3),
            "uplink_codec": ec.uplink_codec,
            "downlink_codec": ec.downlink_codec,
            "d_trainable": self.d_trainable,
            "up_bytes_per_round": self.up_bytes_per_round,
            "down_bytes_per_round": self.down_bytes_per_round,
            "reasons": list(self.reasons),
        }

    def build(self):
        """Instantiate the trainer this plan describes (parameters are
        initialized HERE, not at plan time)."""
        from repro.fed.engine import FederatedTrainer
        tr = FederatedTrainer(self.spec.model, self.spec.firm,
                              self.spec.engine, plan=self)
        if self.spec.sched is None:
            return tr
        from repro.fed.sched.policies import ScheduledTrainer
        return ScheduledTrainer(tr, self.spec.sched)

    def execute(self, rounds: Optional[int] = None) -> List[dict]:
        """build + run; returns the run history."""
        return self.build().run(rounds or self.rounds)


def _dispatch_estimate(algorithm: Algorithm, executor: str,
                       local_mode: str, cohorts, client_fcs,
                       n_part: int, chunk: int) -> float:
    """Engine-counted jit dispatches per round, mirroring the counters
    ``benchmarks/round_throughput.py`` measures.  Participant subsets
    are approximated by the population-mean local-step count."""
    mean_k = sum(fc.local_steps for fc in client_fcs) / len(client_fcs)
    if executor == "fused":
        return 3.0 / chunk                 # stack + fused scan + unstack
    if executor == "loop" or local_mode == "loop":
        return (algorithm.loop_dispatches_per_client_step * n_part * mean_k
                + 4)                       # stack, delta, aggregate, summary
    if local_mode == "cohort":
        return 3 * len(cohorts) + 4        # 3 per cohort + concat + 3
    k = max(fc.local_steps for fc in client_fcs)
    return 2 + algorithm.vec_phase_dispatches(k) + 3


def plan(spec: RunSpec, d_trainable: Optional[int] = None
         ) -> ExecutionPlan:
    """Resolve a RunSpec into an ExecutionPlan via capability queries.

    Raises the same errors execution would (unknown algorithm/policy,
    capability violations such as fedcmoo x heterogeneous local steps or
    fedcmoo x fedbuff) — the whole point of the front door is failing
    before any compilation."""
    fc, ec = spec.firm, spec.engine
    alg = get_algorithm(ec.algorithm)
    alg.validate(fc, ec)
    reasons: List[str] = []

    policy = spec.sched.policy if spec.sched is not None else "sync"
    from repro.fed.sched.policies import _POLICIES
    if policy not in _POLICIES:
        raise ValueError(f"unknown scheduler policy {policy!r}; "
                         f"available: {tuple(sorted(_POLICIES))}")
    if policy == "fedbuff" and alg.caps.single_cohort_required:
        raise ValueError(
            f"fedbuff needs a client-local algorithm; {alg.name} "
            "requires lock-step participants (per-step server exchange)")

    cfcs = client_configs(alg, fc)
    lift = fc.client_preferences is not None
    mode, cohort_plan, mode_reason = resolve_local_mode(
        alg, cfcs, range(fc.n_clients),
        vectorized_clients=ec.vectorized_clients, lift_preference=lift)
    reasons.append(f"local phase: {mode} ({mode_reason})")

    ul = make_codec(ec.uplink_codec)
    dl = make_codec(ec.downlink_codec)
    fused_ok, fused_reason = resolve_fused(alg, mode, ul, dl)
    chunk = max(1, int(ec.fused_rounds))
    if chunk <= 1:
        fused_ok = False
        fused_reason = "fused_rounds <= 1"
    if fused_ok and policy != "sync":
        fused_ok = False
        fused_reason = (f"{policy} policy consults the clock between "
                        "dispatches (host-driven round control)")
    reasons.append(f"fused: {'yes' if fused_ok else 'no'} "
                   f"({fused_reason})")

    executor = ("fused" if fused_ok
                else "loop" if mode == "loop" else "vectorized")

    rounds = spec.rounds or fc.rounds
    fused_chunks: Tuple[int, ...] = ()
    if executor == "fused":
        full, tail = divmod(rounds, chunk)
        fused_chunks = (chunk,) * full + ((tail,) if tail else ())

    d = (trainable_size(spec.model) if d_trainable is None
         else int(d_trainable))
    n_part = min(fc.n_clients,
                 max(1, int(round(fc.participation * fc.n_clients))))
    up = n_part * alg.uplink_bytes_per_participant(fc, ul, d)
    down = n_part * dl.nbytes_static(d)
    cohorts = cohort_summaries(cohort_plan) if cohort_plan else ()

    return ExecutionPlan(
        spec=spec,
        algorithm=alg.name,
        capabilities=alg.caps,
        policy=policy,
        executor=executor,
        local_mode=mode,
        cohorts=cohorts,
        n_clients=fc.n_clients,
        participants_per_round=n_part,
        rounds=rounds,
        fused_chunks=fused_chunks,
        dispatches_per_round=_dispatch_estimate(
            alg, executor, mode, cohorts, cfcs, n_part, chunk),
        d_trainable=d,
        up_bytes_per_round=int(up),
        down_bytes_per_round=int(down),
        reasons=tuple(reasons),
    )


def execute(p: ExecutionPlan, rounds: Optional[int] = None) -> List[dict]:
    """Run an ExecutionPlan end to end; returns the history."""
    return p.execute(rounds)
