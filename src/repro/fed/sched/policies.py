"""Aggregation policies behind one Scheduler protocol.

``ScheduledTrainer`` layers an event-driven simulated clock over the
vectorized round engine: client system profiles (profiles.py) turn the
engine's *measured* payload bytes and per-client step counts into
simulated seconds (repro.core.comms time models), and a policy decides
when the server aggregates:

  sync      today's behavior — every selected client must report before
            the round closes.  The exact-equivalence anchor: it runs
            ``FederatedTrainer.run_round`` unchanged and only adds
            timing, so rewards/λ/bytes are bit-identical to the bare
            engine.  Round time = slowest client.
  deadline  over-select participants (SchedConfig.overselect), predict
            each client's round time from analytic codec bytes + its
            profile, drop those past the deadline, FedAvg the survivors.
            Round time = the deadline when anyone was dropped.
  fedbuff   buffered async: clients run continuously from the broadcast
            version they last received; the server aggregates every B
            arrivals with staleness weights w ∝ (1+s)^-pow
            (core.fedavg.staleness_weights) and redispatches the idle
            clients from the new version.  FIRM's in-client regularizer
            β scales with each client's observed staleness
            (core.firm.staleness_beta) — the paper's drift-mitigation
            knob doubles as the staleness control.  With buffer B = C
            and homogeneous profiles every arrival has staleness 0 and
            the policy degenerates to sync FedAvg bit-for-bit.

All policies compute client work *eagerly* at dispatch time (results
depend only on the anchor params and RNG stream, never on the clock) and
only simulated durations flow through the event queue, so runs are
deterministic under a fixed seed.  Dispatches group in-flight clients by
identical static config (cohort.build_cohorts) — e.g. per-bucket
staleness-scaled β — and run each cohort as one vmapped program; nothing
falls back to the per-client Python loop.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.configs.base import SchedConfig
from repro.core import comms, fedavg, firm
from repro.fed.sched.clock import EventQueue, SimClock
from repro.fed.sched.cohort import build_cohorts
from repro.fed.sched.profiles import sample_profiles
from repro.obs import records as obs_records
from repro.obs.trace import TraceBuilder


def client_round_seconds(profile, down_nbytes: float, up_nbytes: float,
                         local_steps: int, batch_size: int,
                         seq_len: int) -> float:
    """download + local compute + upload, from bytes/tokens and rates.

    The sum of ``core.comms.client_round_segments`` — one definition for
    the policies' timing and the trace emitter's spans, so per-client
    spans always add up to the reported round time."""
    return sum(d for _, d in comms.client_round_segments(
        profile, down_nbytes, up_nbytes, local_steps, batch_size,
        seq_len))


class SyncPolicy:
    """Synchronous barrier: the bare engine round + a max-over-clients
    clock advance.  Bit-identical results to ``FederatedTrainer``.

    When the trainer is configured with ``EngineConfig.fused_rounds > 1``
    (and the fused program applies), the whole horizon runs through
    ``FederatedTrainer.run`` — R rounds per dispatch — and the clock
    annotations are applied per summary afterwards.  The fused path's
    static codec bytes equal the measured payload bytes, so simulated
    durations (and everything derived from them) are unchanged.  The
    deadline/fedbuff policies stay on the per-round engine: their
    control flow consults the clock between dispatches.
    """

    name = "sync"

    def run(self, st: "ScheduledTrainer", rounds: int) -> List[dict]:
        tr = st.trainer
        if tr.ec.fused_rounds > 1 and tr._fused_mode()[0]:
            start = len(tr.history)
            tr.run(rounds)
            return [self._annotate(st, s, round_idx=start + i)
                    for i, s in enumerate(tr.history[start:])]
        return [self.step(st) for _ in range(rounds)]

    def step(self, st: "ScheduledTrainer") -> dict:
        s = st.trainer.run_round()
        return self._annotate(st, s,
                              round_idx=len(st.trainer.history) - 1)

    def _annotate(self, st: "ScheduledTrainer", s: dict,
                  round_idx: Optional[int] = None) -> dict:
        t0 = st.clock.now
        segs = [st.client_segments(c, s["down_nbytes"], s["up_nbytes"][i],
                                   s["local_steps"][i])
                for i, c in enumerate(s["participants"])]
        durs = [sum(d for _, d in seg) for seg in segs]
        dur = max(durs)
        for c, seg in zip(s["participants"], segs):
            st.trace.client_span(c, t0, seg, round_idx=round_idx)
        st.trace.server_span("round", t0, dur,
                             {"policy": self.name, "round": round_idx,
                              "participants": len(durs)})
        st.clock.advance_by(dur)
        st.trace.instant("aggregate", st.clock.now,
                         args={"round": round_idx})
        obs_records.annotate_schedule(
            s, policy=self.name, sim_time=st.clock.now,
            round_duration=dur, dropped=[], client_seconds=durs)
        st.obs.emit_schedule(s, round=round_idx)
        return s


class DeadlinePolicy:
    """Over-select, predict, drop stragglers, FedAvg the survivors.

    Predictions use the *analytic* codec byte model (what a real
    scheduler knows before the round); measured bytes time the survivors
    after the fact.  overselect=1 with an infinite deadline selects and
    keeps exactly the sync participants — the equivalence anchor the
    tests pin.
    """

    name = "deadline"

    def run(self, st: "ScheduledTrainer", rounds: int) -> List[dict]:
        return [self.step(st) for _ in range(rounds)]

    def step(self, st: "ScheduledTrainer") -> dict:
        tr, sc = st.trainer, st.sc
        fc = tr.fc
        target = max(1, int(round(fc.participation * fc.n_clients)))
        n_sel = min(fc.n_clients,
                    max(target, int(round(sc.overselect * target))))
        selected = tr._sample_participants(n=n_sel)
        d = tr.d_trainable
        up_pred = comms.codec_bytes_per_param(tr.ec.uplink_codec, d) * d
        down_pred = comms.codec_bytes_per_param(tr.ec.downlink_codec, d) * d
        pred = {c: st.client_seconds(c, down_pred, up_pred,
                                     tr._client_fcs[c].local_steps)
                for c in selected}
        deadline = sc.deadline_s
        if sc.deadline_quantile is not None:
            deadline = float(np.quantile(list(pred.values()),
                                         sc.deadline_quantile))
        survivors = [c for c in selected if pred[c] <= deadline]
        if not survivors:                 # never stall: keep the fastest
            survivors = [min(selected, key=lambda c: pred[c])]
        dropped = [c for c in selected if c not in survivors]

        t0 = st.clock.now
        s = tr.run_round(participants=survivors)
        round_idx = len(tr.history) - 1
        if dropped:
            # dropped clients were still dispatched and received the
            # broadcast before missing the deadline — their downlink
            # bytes are spent, only their uploads never land
            tr.ledger.down_bytes += len(dropped) * s["down_nbytes"]
            s["down_bytes"] = tr.ledger.down_bytes
            s["comm_bytes"] = tr.ledger.total
        segs = [st.client_segments(c, s["down_nbytes"], s["up_nbytes"][i],
                                   s["local_steps"][i])
                for i, c in enumerate(survivors)]
        durs = [sum(d for _, d in seg) for seg in segs]
        # the server holds the barrier open until the deadline whenever
        # anyone was dropped (it cannot know they won't make it)
        dur = max(durs) if not dropped else max(max(durs), deadline)
        for c, seg in zip(survivors, segs):
            st.trace.client_span(c, t0, seg, round_idx=round_idx)
        for c in dropped:
            # spans from the scheduler's own prediction (analytic bytes):
            # the work was dispatched, the upload never landed
            st.trace.client_span(
                c, t0,
                st.client_segments(c, down_pred, up_pred,
                                   tr._client_fcs[c].local_steps),
                round_idx=round_idx, extra={"dropped": True})
            st.trace.instant("deadline missed", t0 + deadline, client=c,
                             args={"predicted_seconds": round(pred[c], 6)})
        st.trace.server_span("round (deadline)", t0, dur,
                             {"policy": self.name, "round": round_idx,
                              "deadline": deadline,
                              "dropped": len(dropped)})
        st.clock.advance_by(dur)
        st.trace.instant("aggregate", st.clock.now,
                         args={"round": round_idx})
        obs_records.annotate_schedule(
            s, policy=self.name, sim_time=st.clock.now,
            round_duration=dur, dropped=dropped, client_seconds=durs,
            selected=selected, deadline=deadline)
        st.obs.emit_schedule(s, round=round_idx)
        return s


@dataclasses.dataclass
class _Arrival:
    """One client upload in flight: what the server will see land."""
    client: int
    version: int                     # server version it trained from
    decoded: jnp.ndarray             # (d,) delta as the server decodes it
    rewards: jnp.ndarray             # (M,) client mean rewards this phase
    up_nbytes: int
    flow_id: int = 0                 # trace flow arrow: upload -> aggregate


class FedBuffPolicy:
    """Buffered asynchronous aggregation with staleness-weighted deltas
    and staleness-scaled in-client regularization."""

    name = "fedbuff"

    def __init__(self) -> None:
        self._last_cohorts = 0
        # decoded broadcast of the current server version: the anchor
        # aggregation applies deltas to (exactly the engine round's
        # choice, so lossy downlinks keep fedbuff(B=C) == sync)
        self._anchor = None

    def run(self, st: "ScheduledTrainer", rounds: int) -> List[dict]:
        tr, sc = st.trainer, st.sc
        if tr.algorithm.caps.single_cohort_required:
            raise ValueError(
                "fedbuff needs a client-local algorithm; "
                f"{tr.algorithm.name} requires lock-step participants "
                "(per-step server exchange is inherently synchronous)")
        n = tr.fc.n_clients
        buf_size = sc.buffer_size or n
        if not 1 <= buf_size <= n:
            raise ValueError(f"buffer_size {buf_size} outside [1, {n}]")

        def tap(op, t, depth):
            # queue depth = uploads in flight; sampled at dispatch time
            # for pushes, at the arrival's own time for pops
            st.trace.counter("uploads in flight",
                             st.clock.now if op == "push" else t,
                             {"in_flight": depth})

        queue = EventQueue(tap=tap)
        version = 0
        last_staleness: Dict[int, int] = {c: 0 for c in range(n)}
        self._dispatch(st, list(range(n)), version, last_staleness, queue)
        buffer: List[_Arrival] = []
        history: List[dict] = []
        last_agg = st.clock.now
        while len(history) < rounds and queue:
            ev = queue.pop()
            st.clock.advance_to(ev.time)
            buffer.append(ev.item)
            if len(buffer) < buf_size:
                continue
            staleness = [version - a.version for a in buffer]
            flats = jnp.stack([a.decoded for a in buffer])
            tr.global_trainable = tr._aggregate_flat(
                self._anchor, flats, staleness, sc.staleness_pow)
            version += 1
            tr.ledger.next_round()
            for a, s_c in zip(buffer, staleness):
                last_staleness[a.client] = s_c
            # report the same weights the aggregate applied (one formula)
            w = np.asarray(fedavg.staleness_weights(staleness,
                                                    sc.staleness_pow))
            rewards_pc = np.asarray(jnp.stack([a.rewards for a in buffer]))
            summary = obs_records.fedbuff_summary(
                version=version,
                sim_time=st.clock.now,
                round_duration=st.clock.now - last_agg,
                participants=[a.client for a in buffer],
                staleness=staleness,
                staleness_weights=w,
                rewards=rewards_pc.mean(0),
                rewards_per_client=rewards_pc,
                comm_bytes=tr.ledger.total,
                up_bytes=tr.ledger.up_bytes,
                down_bytes=tr.ledger.down_bytes,
            )
            st.trace.server_span(f"buffer v{version}", last_agg,
                                 st.clock.now - last_agg,
                                 {"policy": self.name,
                                  "arrivals": len(buffer)})
            st.trace.instant(f"aggregate v{version}", st.clock.now,
                             args={"staleness": staleness})
            for a, s_c in zip(buffer, staleness):
                st.trace.flow_end("upload", st.clock.now, a.flow_id,
                                  args={"client": a.client,
                                        "staleness": s_c})
            st.obs.emit_round(summary, round=version - 1)
            last_agg = st.clock.now
            idle = [a.client for a in buffer]
            buffer = []
            history.append(summary)
            if len(history) < rounds:
                # idle clients restart from the new version; skipped
                # after the last aggregation so no discarded work runs
                self._dispatch(st, idle, version, last_staleness, queue)
                summary["cohorts"] = self._last_cohorts
            else:
                summary["cohorts"] = 0
        return history

    def _dispatch(self, st: "ScheduledTrainer", clients: List[int],
                  version: int, last_staleness: Dict[int, int],
                  queue: EventQueue) -> None:
        """Broadcast the current version to ``clients``, run their local
        phases eagerly (cohort-vectorized), encode their uplinks, and
        schedule the arrival events."""
        tr, sc = st.trainer, st.sc
        from repro.fed import engine as engine_lib
        dl_payload, tr._downlink_state, broadcast = \
            tr.downlink_codec.roundtrip(tr.global_trainable,
                                        tr._downlink_state,
                                        key=tr._next_key())
        self._anchor = broadcast
        down_nbytes = comms.measured_bytes(dl_payload)
        for _ in clients:
            tr.ledger.send_down(dl_payload)
        # per-client config with staleness-scaled β, bucketed so a handful
        # of static configs (and vmapped cohorts / compiles) cover every
        # staleness level
        pairs = []
        for c in clients:
            base = tr._client_fcs[c]
            bucket = min(int(last_staleness[c]), sc.staleness_bucket_max)
            beta = firm.staleness_beta(base.beta, bucket,
                                       sc.staleness_beta_gain,
                                       sc.staleness_beta_cap)
            pairs.append((c, dataclasses.replace(base, beta=beta)))
        plan = build_cohorts(pairs,
                             lift_preference=tr._stacked_pref is not None)
        self._last_cohorts = len(plan)
        for co in plan:
            members = list(co.members)
            res = tr._local_phase_vectorized(co.cfc, members, broadcast)
            flats = engine_lib._delta_flat_jit(res.stacked_trainable,
                                               broadcast)
            tr.jit_dispatches += 1
            for i, c in enumerate(members):
                payload, tr._uplink_state[c], dec = \
                    tr.uplink_codec.roundtrip_flat(
                        flats[i], tr._delta_spec, tr._uplink_state[c],
                        key=tr._next_key())
                tr.ledger.send_up(payload)
                segs = st.client_segments(c, down_nbytes, payload.nbytes,
                                          co.cfc.local_steps)
                dur = sum(d for _, d in segs)
                t_end = st.trace.client_span(c, st.clock.now, segs,
                                             extra={"version": version})
                fid = st.trace.flow_start("upload", t_end, client=c,
                                          args={"version": version})
                queue.push(st.clock.now + dur,
                           _Arrival(c, version, dec, res.rewards_pc[i],
                                    int(payload.nbytes), fid))


_POLICIES = {"sync": SyncPolicy, "deadline": DeadlinePolicy,
             "fedbuff": FedBuffPolicy}


def make_policy(name: str):
    if name not in _POLICIES:
        raise ValueError(f"unknown scheduler policy {name!r}; "
                         f"available: {tuple(sorted(_POLICIES))}")
    return _POLICIES[name]()


class ScheduledTrainer:
    """Simulated-time federation: a FederatedTrainer + client profiles +
    an aggregation policy on an event-driven clock.

        tr = FederatedTrainer(cfg, fc, ec)
        st = ScheduledTrainer(tr, SchedConfig(policy="deadline",
                                              profile="bimodal",
                                              deadline_quantile=0.7))
        history = st.run(rounds)     # entries carry sim_time etc.

    One history entry per server aggregation.  The underlying trainer is
    shared mutable state — don't reuse it across ScheduledTrainers.
    """

    def __init__(self, trainer, sc: Optional[SchedConfig] = None):
        self.trainer = trainer
        self.sc = SchedConfig() if sc is None else sc
        self.profiles = sample_profiles(trainer.fc.n_clients,
                                        self.sc.profile,
                                        self.sc.profile_seed)
        self.clock = SimClock()
        self.policy = make_policy(self.sc.policy)
        self.history: List[dict] = []
        # telemetry: round records ride the engine's pipeline; the
        # policies additionally feed the simulated-time trace (client
        # phase spans, aggregation instants, drop/staleness annotations)
        self.obs = trainer.obs
        self.trace = TraceBuilder()
        # a legacy-constructed trainer planned itself without this
        # SchedConfig; re-resolve so trainer.plan reflects the policy it
        # will actually run under (e.g. deadline/fedbuff force per-round
        # execution even when the bare engine would fuse).  An
        # algorithm x policy combination plan() rejects is left to raise
        # from run() (the legacy contract: construction succeeds).
        if trainer.plan.spec.sched is not self.sc:
            from repro.fed import api
            try:
                trainer.plan = api.plan(
                    api.RunSpec(model=trainer.cfg, firm=trainer.fc,
                                engine=trainer.ec, sched=self.sc),
                    d_trainable=trainer.d_trainable)
            except ValueError:
                pass

    def client_seconds(self, c: int, down_nbytes: float, up_nbytes: float,
                       local_steps: int) -> float:
        seq = self.trainer.ec.prompt_len + self.trainer.ec.max_new
        return client_round_seconds(self.profiles[c], down_nbytes,
                                    up_nbytes, local_steps,
                                    self.trainer.fc.batch_size, seq)

    def client_segments(self, c: int, down_nbytes: float,
                        up_nbytes: float, local_steps: int):
        """(phase, seconds) decomposition of ``client_seconds`` — what
        the trace emitter renders as consecutive spans."""
        seq = self.trainer.ec.prompt_len + self.trainer.ec.max_new
        return comms.client_round_segments(self.profiles[c], down_nbytes,
                                           up_nbytes, local_steps,
                                           self.trainer.fc.batch_size, seq)

    def run(self, rounds: Optional[int] = None) -> List[dict]:
        out = self.policy.run(self, rounds or self.trainer.fc.rounds)
        self.history.extend(out)
        return self.history

    def export_trace(self, path: str, host_spans=None) -> dict:
        """Write the accumulated schedule as Chrome/Perfetto trace-event
        JSON (open at https://ui.perfetto.dev).  ``host_spans`` optionally
        adds ``repro.obs.jitwatch`` spans as a host wall-clock process.
        Validates before writing; returns the trace dict."""
        if host_spans:
            self.trace.add_host_spans(host_spans)
        return self.trace.write(path)
