"""Federated scheduler subsystem: simulated-time client heterogeneity,
deadline / buffered-async aggregation, cohort-vectorized dispatch.

    from repro.fed.sched import ScheduledTrainer
    from repro.configs.base import SchedConfig

See README.md in this package for the time model and policy semantics.

``policies`` is exposed lazily (PEP 562): the engine imports
``sched.cohort`` at module load, so this package's eager imports must
not reach back into ``repro.fed.engine``.
"""
from repro.fed.sched.clock import EventQueue, SimClock
from repro.fed.sched.cohort import Cohort, build_cohorts, cohort_summaries
from repro.fed.sched.profiles import (ClientProfile, PROFILE_PRESETS,
                                      sample_profiles)

_LAZY = ("ScheduledTrainer", "SyncPolicy", "DeadlinePolicy",
         "FedBuffPolicy", "make_policy", "client_round_seconds")


def __getattr__(name):
    if name in _LAZY:
        from repro.fed.sched import policies
        return getattr(policies, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "EventQueue", "SimClock", "Cohort", "build_cohorts",
    "cohort_summaries", "ClientProfile", "PROFILE_PRESETS",
    "sample_profiles", *_LAZY,
]
