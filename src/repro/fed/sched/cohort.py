"""Group-by-config cohort planning for vectorized dispatch.

jax.vmap requires every lane to share one static configuration; PR 2's
engine therefore fell all the way back to the per-client Python loop the
moment any per-client ``FIRMConfig`` diverged.  A *cohort plan* instead
partitions the in-flight clients into groups with identical static
config — preference stripped when it is lifted to a traced (C, M) array
— so each group runs as ONE vmapped program.  Heterogeneous local-step
counts (``FIRMConfig.client_local_steps``), per-bucket staleness-scaled
β under the async scheduler, and future per-client divergences all cost
one extra dispatch per distinct config instead of C×K dispatches.

Grouping is insertion-ordered (first client with a new config opens its
cohort), so plans are deterministic for a fixed participant order.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from repro.configs.base import FIRMConfig


@dataclasses.dataclass(frozen=True)
class Cohort:
    """One vmapped dispatch group: shared static config + member clients."""
    cfc: FIRMConfig
    members: Tuple[int, ...]


def static_config_key(fc: FIRMConfig, lift_preference: bool) -> FIRMConfig:
    """The config as vmap sees it: preference removed iff it rides a
    traced array instead of the static dataclass field."""
    if lift_preference:
        return dataclasses.replace(fc, preference=None)
    return fc


def build_cohorts(pairs: Sequence[Tuple[int, FIRMConfig]],
                  lift_preference: bool = False) -> List[Cohort]:
    """[(client_id, per-client config)] -> ordered list of Cohorts.

    Clients whose static keys match share a cohort; member order inside a
    cohort and cohort order both follow first appearance in ``pairs``.
    """
    groups: Dict[FIRMConfig, List[int]] = {}
    for c, fc in pairs:
        groups.setdefault(static_config_key(fc, lift_preference),
                          []).append(c)
    return [Cohort(cfc=k, members=tuple(v)) for k, v in groups.items()]


def cohort_summaries(plan: Sequence[Cohort]) -> Tuple[Tuple[int, int], ...]:
    """(n_members, local_steps) per cohort — the ExecutionPlan's compact
    view of the dispatch structure (JSON-able, order-preserving)."""
    return tuple((len(co.members), co.cfc.local_steps) for co in plan)
