"""Deterministic event-driven simulated clock for the federated scheduler.

The scheduler never sleeps: client work is *computed* eagerly (results
depend only on the dispatch anchor and RNG stream, never on wall time)
and only its simulated duration flows through this module.  Events are
totally ordered by (time, insertion sequence), so simultaneous arrivals
— e.g. a homogeneous cohort dispatched together — resolve in dispatch
order and every run with the same seed replays the exact same schedule.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, List, Tuple


class SimClock:
    """Monotone simulated time in seconds."""

    def __init__(self) -> None:
        self.now: float = 0.0

    def advance_to(self, t: float) -> None:
        if t < self.now - 1e-12:
            raise ValueError(f"clock moving backwards: {t} < {self.now}")
        self.now = max(self.now, float(t))

    def advance_by(self, dt: float) -> None:
        if dt < 0:
            raise ValueError(f"negative duration {dt}")
        self.now += float(dt)


@dataclasses.dataclass(frozen=True)
class Event:
    time: float
    seq: int                         # insertion order: deterministic ties
    item: Any = dataclasses.field(compare=False, default=None)


class EventQueue:
    """Min-heap of Events with a deterministic (time, seq) total order."""

    def __init__(self) -> None:
        self._heap: List[Tuple[float, int, Any]] = []
        self._seq = 0

    def push(self, time: float, item: Any) -> None:
        heapq.heappush(self._heap, (float(time), self._seq, item))
        self._seq += 1

    def pop(self) -> Event:
        time, seq, item = heapq.heappop(self._heap)
        return Event(time, seq, item)

    def peek_time(self) -> float:
        return self._heap[0][0]

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)
