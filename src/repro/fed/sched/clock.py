"""Deterministic event-driven simulated clock for the federated scheduler.

The scheduler never sleeps: client work is *computed* eagerly (results
depend only on the dispatch anchor and RNG stream, never on wall time)
and only its simulated duration flows through this module.  Events are
totally ordered by (time, insertion sequence), so simultaneous arrivals
— e.g. a homogeneous cohort dispatched together — resolve in dispatch
order and every run with the same seed replays the exact same schedule.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Callable, List, Optional, Tuple


class SimClock:
    """Monotone simulated time in seconds."""

    def __init__(self) -> None:
        self.now: float = 0.0

    def advance_to(self, t: float) -> None:
        if t < self.now - 1e-12:
            raise ValueError(f"clock moving backwards: {t} < {self.now}")
        self.now = max(self.now, float(t))

    def advance_by(self, dt: float) -> None:
        if dt < 0:
            raise ValueError(f"negative duration {dt}")
        self.now += float(dt)


@dataclasses.dataclass(frozen=True)
class Event:
    time: float
    seq: int                         # insertion order: deterministic ties
    item: Any = dataclasses.field(compare=False, default=None)


class EventQueue:
    """Min-heap of Events with a deterministic (time, seq) total order.

    ``tap``, when given, observes every mutation as ``tap(op, time,
    depth)`` with op in {"push", "pop"}, the event's scheduled time, and
    the post-mutation queue depth — a pure read-out (it cannot reorder
    or reject events) that the obs trace renders as an in-flight counter
    track.
    """

    def __init__(self, tap: Optional[Callable[[str, float, int], None]]
                 = None) -> None:
        self._heap: List[Tuple[float, int, Any]] = []
        self._seq = 0
        self._tap = tap

    def push(self, time: float, item: Any) -> None:
        heapq.heappush(self._heap, (float(time), self._seq, item))
        self._seq += 1
        if self._tap is not None:
            self._tap("push", float(time), len(self._heap))

    def pop(self) -> Event:
        time, seq, item = heapq.heappop(self._heap)
        if self._tap is not None:
            self._tap("pop", time, len(self._heap))
        return Event(time, seq, item)

    def peek_time(self) -> float:
        return self._heap[0][0]

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)
