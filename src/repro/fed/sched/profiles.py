"""Per-client system profiles: compute speed and link bandwidths.

A ``ClientProfile`` is the scheduler's model of one device: how fast it
burns through local-PPO token work and how fast its links move payload
bytes (repro.core.comms time-from-bytes models).  Profiles are sampled
once per run from a named preset distribution so heterogeneity is
reproducible under a seed:

  homogeneous  every client identical (the exact-equivalence anchor:
               all policies degenerate to synchronous rounds)
  uniform      rates drawn U[low, high] per dimension — mild spread
  lognormal    heavy-tailed rates around a median — realistic fleets
  bimodal      edge-vs-datacenter mixture: most clients are slow edge
               devices, a minority are datacenter-fast.  The straggler
               regime where deadline/async policies dominate sync.

Rates are tokens/s for compute and bytes/s for links.  Absolute values
are smoke-scale stand-ins; only the *ratios* drive policy comparisons.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class ClientProfile:
    tokens_per_sec: float
    up_bytes_per_sec: float
    down_bytes_per_sec: float


def _homogeneous(n: int, rng) -> Tuple[ClientProfile, ...]:
    return tuple(ClientProfile(4096.0, 12.5e6, 50e6) for _ in range(n))


def _uniform(n: int, rng) -> Tuple[ClientProfile, ...]:
    return tuple(ClientProfile(
        tokens_per_sec=float(rng.uniform(1024, 8192)),
        up_bytes_per_sec=float(rng.uniform(2e6, 25e6)),
        down_bytes_per_sec=float(rng.uniform(10e6, 100e6)))
        for _ in range(n))


def _lognormal(n: int, rng) -> Tuple[ClientProfile, ...]:
    # medians match the homogeneous preset; sigma=0.8 gives ~5x IQR spread
    def draw(median):
        return float(median * rng.lognormal(0.0, 0.8))
    return tuple(ClientProfile(draw(4096.0), draw(12.5e6), draw(50e6))
                 for _ in range(n))


def _bimodal(n: int, rng) -> Tuple[ClientProfile, ...]:
    # 75% edge devices (slow compute, 10 Mbps uplink), 25% datacenter
    # nodes ~100x faster: the max/median round-time ratio sync pays
    out = []
    for _ in range(n):
        if rng.uniform() < 0.75:
            out.append(ClientProfile(512.0, 1.25e6, 5e6))
        else:
            out.append(ClientProfile(65536.0, 1.25e9, 1.25e9))
    return tuple(out)


PROFILE_PRESETS = {
    "homogeneous": _homogeneous,
    "uniform": _uniform,
    "lognormal": _lognormal,
    "bimodal": _bimodal,
}


def sample_profiles(n_clients: int, preset: str = "homogeneous",
                    seed: int = 0) -> Tuple[ClientProfile, ...]:
    """Draw n client profiles from a named preset, deterministic in seed."""
    if preset not in PROFILE_PRESETS:
        raise ValueError(f"unknown profile preset {preset!r}; "
                         f"available: {tuple(sorted(PROFILE_PRESETS))}")
    rng = np.random.default_rng(seed)
    return PROFILE_PRESETS[preset](n_clients, rng)
