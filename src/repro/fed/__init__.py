from repro.fed.engine import EngineConfig, FederatedTrainer  # noqa
from repro.fed.sched.policies import ScheduledTrainer  # noqa

__all__ = ["FederatedTrainer", "EngineConfig", "ScheduledTrainer"]
