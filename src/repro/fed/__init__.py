from repro.fed.algorithms import (Algorithm, Capabilities,  # noqa
                                  available_algorithms, get_algorithm,
                                  register_algorithm)
from repro.fed.api import (EngineConfig, ExecutionPlan, RunSpec,  # noqa
                           execute, plan)
from repro.fed.engine import FederatedTrainer  # noqa
from repro.fed.sched.policies import ScheduledTrainer  # noqa

__all__ = [
    "FederatedTrainer", "EngineConfig", "ScheduledTrainer",
    "RunSpec", "ExecutionPlan", "plan", "execute",
    "Algorithm", "Capabilities", "available_algorithms", "get_algorithm",
    "register_algorithm",
]
