from repro.fed.engine import EngineConfig, FederatedTrainer  # noqa

__all__ = ["FederatedTrainer", "EngineConfig"]
