"""Federated alignment simulation engine (paper §5 experimental loop).

Simulates the server + C clients protocol end-to-end at laptop scale:
generation with the current local policy, synthetic reward scoring, the
FIRM (or baseline) local update, FedAvg aggregation, and full metric /
communication accounting.  Algorithms:

  'firm'       — paper Alg. 1 (in-client regularized MGDA)
  'firm_unreg' — β = 0 ablation (RQ2)
  'fedcmoo'    — server-centric MGDA baseline (RQ1, Askin et al. 2024)
  'linear'     — fixed-weight linear scalarization (implicit baseline)

All uplink/downlink traffic flows through the repro.comms codec layer
(EngineConfig.uplink_codec / downlink_codec registry specs): clients
upload encoded *deltas* against the decoded broadcast they trained from,
error-feedback residuals stay client-local, and the ledger records the
measured Payload bytes (int8 uplink ≈ 1/4 of raw f32).
"""
from __future__ import annotations

import dataclasses
import functools
from functools import partial
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.comms import ErrorFeedback, make_codec
from repro.configs.base import FIRMConfig, ModelConfig
from repro.core import comms, drift, fedavg, fedcmoo
from repro.data.partition import make_client_datasets
from repro.models import transformer
from repro.models.common import merge_trainable, split_trainable, tree_size
from repro.rlhf import local as local_lib
from repro.rlhf import ppo, rewards as rewards_lib
from repro.rlhf.sampling import generate


# Jitted callables are memoized on the (hashable, frozen) configs so every
# trainer with the same architecture + FIRM hyperparameters shares one
# trace/compile per process — the test suite and benchmark sweeps build
# dozens of identically-configured trainers.
@functools.lru_cache(maxsize=None)
def _jit_local_step(cfg: ModelConfig, cfc: FIRMConfig):
    return jax.jit(partial(local_lib.firm_local_step, cfg, cfc))


@functools.lru_cache(maxsize=None)
def _jit_ref_logprobs(cfg: ModelConfig):
    def ref_lp(ref_params, tokens):
        out = transformer.forward_seq(cfg, ref_params, tokens)
        return ppo.token_logprobs(out["logits"], tokens)
    return jax.jit(ref_lp)


@dataclasses.dataclass
class EngineConfig:
    algorithm: str = "firm"
    prompt_len: int = 8
    max_new: int = 24
    dirichlet_alpha: float = 0.3
    seed: int = 0
    heterogeneous_rms: bool = False      # half the clients use the alt RM
    fedcmoo_compress_rank: Optional[int] = None
    linear_weights: Optional[Sequence[float]] = None
    # comms codecs (repro.comms registry specs, e.g. "int8+ef")
    uplink_codec: str = "identity"       # client -> server deltas/grads
    downlink_codec: str = "identity"     # server -> client broadcast


class FederatedTrainer:
    def __init__(self, cfg: ModelConfig, fc: FIRMConfig,
                 ec: Optional[EngineConfig] = None):
        # default must be constructed per instance: a shared EngineConfig
        # default would leak mutations across trainers
        ec = EngineConfig() if ec is None else ec
        self.cfg, self.fc, self.ec = cfg, fc, ec
        key = jax.random.PRNGKey(ec.seed)
        self.params = transformer.init_params(cfg, key)
        trainable, frozen = split_trainable(self.params)
        self.frozen = frozen
        self.ref_params = self.params                     # frozen reference
        self.global_trainable = trainable
        self.client_states = [
            local_lib.init_client_state(trainable, fc.n_objectives,
                                        cfg.d_model, fc.kl_coef_init)
            for _ in range(fc.n_clients)]
        self.datasets = make_client_datasets(
            fc.n_clients, cfg.vocab, ec.prompt_len,
            alpha=ec.dirichlet_alpha, seed=ec.seed)
        self.reward_fns = []
        for c in range(fc.n_clients):
            variant = ("alt" if ec.heterogeneous_rms and
                       c >= fc.n_clients // 2 else "default")
            self.reward_fns.append(rewards_lib.make_reward_fns(
                cfg.vocab, fc.n_objectives, variant=variant,
                length_tolerance=max(4, ec.max_new // 2)))
        self.ledger = comms.CommsLedger()
        # comms codecs: one stateless codec per link; per-client error
        # feedback residuals stay in client-indexed slots here
        self.uplink_codec = make_codec(ec.uplink_codec)
        self.downlink_codec = make_codec(ec.downlink_codec)
        self._uplink_state = [None] * fc.n_clients
        self._downlink_state = None
        self.d_trainable = tree_size(trainable)
        self.history: List[dict] = []
        self._rng = jax.random.PRNGKey(ec.seed + 1)
        # per-client FIRM configs (pluralistic preferences, §6 future work)
        self._client_fcs = []
        base_fc = self._fc_for_algorithm()
        for c in range(fc.n_clients):
            cfc = base_fc
            if fc.client_preferences is not None:
                cfc = dataclasses.replace(
                    base_fc, preference=fc.client_preferences[c])
            self._client_fcs.append(cfc)
        self._jit_steps = [_jit_local_step(cfg, cfc)
                           for cfc in self._client_fcs]
        self._jit_step = self._jit_steps[0]
        self._jit_ref_lp = partial(_jit_ref_logprobs(cfg), self.ref_params)

    # ------------------------------------------------------------------
    def _fc_for_algorithm(self) -> FIRMConfig:
        fc = self.fc
        if self.ec.algorithm == "firm_unreg":
            fc = dataclasses.replace(fc, beta=0.0)
        return fc

    def _next_key(self):
        self._rng, k = jax.random.split(self._rng)
        return k

    def _make_batch(self, c: int) -> ppo.PPOBatch:
        prompts = self.datasets[c].next_batch(self.fc.batch_size)
        params = merge_trainable(self.client_states[c].trainable,
                                 self.frozen)
        tokens, old_lp, mask = generate(self.cfg, params, prompts,
                                        self._next_key(),
                                        max_new=self.ec.max_new)
        r = rewards_lib.score_batch(self.reward_fns[c], tokens, mask)
        ref_lp = self._jit_ref_lp(tokens)
        return ppo.PPOBatch(tokens, mask, old_lp, ref_lp, r)

    # ------------------------------------------------------------------
    def _sample_participants(self) -> List[int]:
        fc = self.fc
        n = max(1, int(round(fc.participation * fc.n_clients)))
        if n >= fc.n_clients:
            return list(range(fc.n_clients))
        idx = jax.random.choice(self._next_key(), fc.n_clients, (n,),
                                replace=False)
        return sorted(int(i) for i in idx)

    def _grad_codec(self):
        """Codec for per-step gradient uploads (fedcmoo/linear): error
        feedback is defined per client *stream*, not per objective, so the
        M parallel gradient trees use the EF-stripped inner codec."""
        ul = self.uplink_codec
        return ul.inner if isinstance(ul, ErrorFeedback) else ul

    def run_round(self) -> dict:
        fc = self._fc_for_algorithm()
        participants = self._sample_participants()
        # broadcast θ_t through the downlink codec; every client receives
        # (and trains from) the same decoded broadcast
        dl_payload, self._downlink_state, broadcast = \
            self.downlink_codec.roundtrip(
                self.global_trainable, self._downlink_state,
                key=self._next_key())
        for c in participants:
            self.client_states[c] = self.client_states[c]._replace(
                trainable=broadcast)
            self.ledger.send_down(dl_payload)
        round_metrics = []
        if self.ec.algorithm in ("firm", "firm_unreg"):
            for k in range(fc.local_steps):
                for c in participants:
                    batch = self._make_batch(c)
                    self.client_states[c], m = self._jit_steps[c](
                        self.client_states[c], self.frozen, batch)
                    m["client"] = c
                    round_metrics.append(m)
        elif self.ec.algorithm == "fedcmoo":
            grad_codec = self._grad_codec()
            for k in range(fc.local_steps):
                per_client = []
                server_grads = []
                for c in participants:
                    batch = self._make_batch(c)
                    grads, losses, extras = local_lib.fedcmoo_local_grads(
                        self.cfg, fc, self.client_states[c], self.frozen,
                        batch)
                    per_client.append((grads, extras, batch.rewards.mean(0)))
                    # gradients go up every local step: the O(CMd) cost;
                    # the server solves λ from what it actually receives
                    # (codec error feeds the q-term, Askin et al. Rmk 4.6)
                    received = []
                    for g in grads:
                        gp, _, dec = grad_codec.roundtrip(
                            g, key=self._next_key())
                        self.ledger.send_up(gp)
                        received.append(dec)
                    server_grads.append(received)
                lam = fedcmoo.fedcmoo_round_lambda(
                    server_grads,
                    compress_rank=self.ec.fedcmoo_compress_rank,
                    key=self._next_key())
                for ci, c in enumerate(participants):
                    grads, extras, rmean = per_client[ci]
                    self.client_states[c], m = local_lib.fedcmoo_local_apply(
                        fc, self.client_states[c], grads, lam, extras)
                    m["client"] = c
                    m["rewards"] = rmean
                    round_metrics.append(m)
        elif self.ec.algorithm == "linear":
            w = jnp.asarray(self.ec.linear_weights
                            or [1.0 / fc.n_objectives] * fc.n_objectives,
                            jnp.float32)
            for k in range(fc.local_steps):
                for c in participants:
                    batch = self._make_batch(c)
                    grads, losses, extras = local_lib.fedcmoo_local_grads(
                        self.cfg, fc, self.client_states[c], self.frozen,
                        batch)
                    self.client_states[c], m = local_lib.fedcmoo_local_apply(
                        fc, self.client_states[c], grads, w, extras)
                    m["client"] = c
                    m["rewards"] = batch.rewards.mean(0)
                    round_metrics.append(m)
        else:
            raise ValueError(self.ec.algorithm)

        # participating clients transmit adapted-param deltas through the
        # uplink codec (residuals stay client-local); the server FedAvgs
        # the decoded deltas on top of the broadcast it anchored them to
        decoded_deltas = []
        for c in participants:
            delta = jax.tree_util.tree_map(
                lambda a, b: a - b, self.client_states[c].trainable,
                broadcast)
            payload, self._uplink_state[c], dec = \
                self.uplink_codec.roundtrip(
                    delta, self._uplink_state[c], key=self._next_key())
            self.ledger.send_up(payload)
            decoded_deltas.append(dec)
        mean_delta = fedavg.fedavg(decoded_deltas)
        self.global_trainable = jax.tree_util.tree_map(
            lambda b, d: b + d, broadcast, mean_delta)
        self.ledger.next_round()

        lams = jnp.stack([np.asarray(m["lam"]) for m in round_metrics
                          if "lam" in m][-len(participants):])
        summary = {
            "rewards": np.mean(np.stack(
                [np.asarray(m["rewards"]) for m in round_metrics]), axis=0),
            "lam_mean": np.asarray(lams.mean(0)),
            "lam_disagreement": float(
                drift.lambda_disagreement(lams)["pairwise_mean"]),
            "param_drift": float(drift.param_drift(
                [self.client_states[c].trainable for c in participants])),
            "kl": float(np.mean([np.asarray(m["kl"])
                                 for m in round_metrics])),
            "comm_bytes": self.ledger.total,
            "up_bytes": self.ledger.up_bytes,
            "down_bytes": self.ledger.down_bytes,
            "participants": participants,
            "per_client_lam": np.asarray(lams),
        }
        self.history.append(summary)
        return summary

    def run(self, rounds: Optional[int] = None) -> List[dict]:
        for _ in range(rounds or self.fc.rounds):
            self.run_round()
        return self.history
