"""Federated alignment simulation engine (paper §5 experimental loop).

Simulates the server + C clients protocol end-to-end at laptop scale:
generation with the current local policy, synthetic reward scoring, the
local update, FedAvg aggregation, and full metric / communication
accounting.  WHICH update runs is owned by the ``Algorithm`` objects in
``repro.fed.algorithms`` (paper Alg. 1, its β = 0 ablation, linear
scalarization, the server-centric MGDA baseline, and anything else the
registry holds) — this module contains no algorithm-name dispatch at
all: every path decision is a CAPABILITY query (``Algorithm.caps``)
resolved through ``repro.fed.api``, and the declarative front door
(``RunSpec -> plan() -> ExecutionPlan``) exposes the same decisions
for inspection before anything compiles.

All uplink/downlink traffic flows through the repro.comms codec layer
(EngineConfig.uplink_codec / downlink_codec registry specs): clients
upload encoded *deltas* against the decoded broadcast they trained from,
error-feedback residuals stay client-local, and the ledger records the
measured Payload bytes (int8 uplink ≈ 1/4 of raw f32).

Round execution (vectorized round engine)
-----------------------------------------
Two interchangeable local-phase paths:

* **vectorized** (default, ``EngineConfig.vectorized_clients``):
  participant ``ClientState``s are held as ONE pytree with a leading
  client axis; prompt sampling (``data.partition.sample_prompt_block``),
  rollout generation, reward scoring (banded, per-client parameters),
  reference logprobs and the local update are all ``jax.vmap``ed over
  that axis, and the K local steps run under one ``jax.lax.scan`` — the
  entire local phase is a single jitted dispatch with the stacked state
  donated.  Per-step metrics (stacked λ / KL / rewards) stay
  device-resident and transfer to host once per round.  The client→server
  delta and FedAvg are single batched tree ops over the stacked axis.
* **per-client loop**: the original Python loop (C × K dispatches), kept
  for equivalence testing and as the capability fallback.

vmap groups clients by IDENTICAL static config via a *cohort plan*
(repro.fed.sched.cohort): participants partition into groups with equal
static ``FIRMConfig`` (preference lifted to a traced (C, M) array when
``client_preferences`` is set), and each cohort runs as one vmapped
program — e.g. heterogeneous per-client ``client_local_steps``
(FedMOA-style rates) costs one dispatch per distinct K.  Generation
keys are drawn in the canonical loop order (step-major over all
participants) and sliced per cohort, so multi-cohort rounds stay
equivalent to the per-client loop.  Algorithms declaring
``single_cohort_required`` (a lock-step per-step server exchange) fall
back to the loop when configs diverge; algorithms whose server exchange
is host-driven (``traced_server_exchange=False``) route the vectorized
phase through their own ``exchange_phase_vectorized`` hook.  The uplink
codec runs at a *stacked* Payload boundary
(``Codec.roundtrip_stacked``): quantize codecs encode all C client
deltas in one batched kernel dispatch, byte-identical to per-client
encodes.

Participation sampling draws from a NAMED PRNG stream keyed on
(seed, round index), independent of how many keys generation / codecs
consumed — so the scheduler subsystem's deadline over-selection and
dropout (repro.fed.sched) reproduce the same client draws across
policies.

Fused multi-round execution
---------------------------
``EngineConfig.fused_rounds = R`` lifts the WHOLE round — participation
fold-in, downlink broadcast, the vectorized local phase, delta
extraction, the stacked uplink roundtrip, and the FedAvg aggregate —
into a round-level ``jax.lax.scan``: R rounds run as ONE jitted dispatch
with ONE host transfer at the end of the chunk (see ``FusedCarry`` for
the donated carry layout and ``_jit_fused_rounds`` for the body).  The
codecs run through their traced contract (``repro.comms``:
``roundtrip_traced*`` with explicit array state, ``nbytes_static`` byte
accounting), so the comms ledger and the scheduler's time models keep
exact bytes with zero per-round host syncs.  Results are bit-identical
to the per-round path: the body replicates ``run_round``'s PRNG split
sequence exactly, and the error-feedback residual is computed in the
same jitted composition on both paths (XLA contracts the dequantize
multiply into the residual subtract; doing it identically everywhere is
what keeps the trajectories exact).  ``run()`` chunks the horizon by R
when ``api.resolve_fused`` grants it — the algorithm declares
``fusable`` (which requires a traced server exchange), the population
forms one cohort, and both codecs support the traced contract — and
falls back to per-round execution otherwise; the ``sync`` scheduler
policy rides the fused path unchanged while the deadline/fedbuff
policies are host-driven between dispatches and stay per-round.
"""
from __future__ import annotations

import dataclasses
import functools
from functools import partial
from typing import List, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.comms import make_codec
from repro.comms import codec as codec_lib
from repro.configs.base import FIRMConfig, ModelConfig
from repro.core import comms, drift, fedavg
from repro.data.partition import make_client_datasets, sample_prompt_block
from repro.fed import api as api_lib
from repro.fed.algorithms import client_configs, get_algorithm
from repro.fed.api import EngineConfig  # noqa: F401  (canonical home is api)
from repro.models import transformer
from repro.models.common import merge_trainable, split_trainable, tree_size
from repro.obs import jitwatch
from repro.obs import records as obs_records
from repro.obs.metrics import MetricsPipeline
from repro.rlhf import local as local_lib
from repro.rlhf import ppo, rewards as rewards_lib
from repro.rlhf.sampling import generate


@functools.lru_cache(maxsize=None)
def _jit_ref_logprobs(cfg: ModelConfig):
    def ref_lp(ref_params, tokens):
        out = transformer.forward_seq(cfg, ref_params, tokens)
        return ppo.token_logprobs(out["logits"], tokens)
    return jitwatch.wrap("ref_logprobs", jax.jit(ref_lp))


def _make_round_fn(cfg: ModelConfig, cfc: FIRMConfig, kernel: str,
                   prompt_len: int, max_new: int, length_tol: int,
                   has_pref: bool):
    """One round's entire local phase as a pure function.

    vmap over the stacked client axis x lax.scan over the K local steps:
    sampling, generation, reward scoring, reference logprobs and the
    local update all fuse into one program.  ``kernel`` names the
    Algorithm whose ``traced_step`` runs inside the vmap (algorithms
    that lower to the same program share a kernel name and therefore a
    compile).  Jitted standalone by ``_jit_vec_round`` (the per-round
    path) and inlined into the round-level scan by
    ``_jit_fused_rounds``.
    """
    alg = get_algorithm(kernel)
    k_steps = cfc.local_steps
    m = cfc.n_objectives
    b = cfc.batch_size

    def round_fn(state, frozen, ref_params, seeds, counts0, probs,
                 band_h, band_x, gen_keys, pref, extra):

        def one_client(st, prompts, key, bh, bx, p):
            params = merge_trainable(st.trainable, frozen)
            tokens, old_lp, mask = generate(cfg, params, prompts, key,
                                            max_new=max_new)
            r = rewards_lib.score_batch_banded(bh, bx, tokens, mask, m,
                                               length_tol)
            ref_out = transformer.forward_seq(cfg, ref_params, tokens)
            ref_lp = ppo.token_logprobs(ref_out["logits"], tokens)
            batch = ppo.PPOBatch(tokens, mask, old_lp, ref_lp, r)
            return alg.traced_step(cfg, cfc, st, frozen, batch, p, extra)

        vstep = jax.vmap(one_client,
                         in_axes=(0, 0, 0, 0, 0, 0 if has_pref else None))

        def body(carry, xs):
            step_idx, keys_c = xs
            prompts = sample_prompt_block(seeds, counts0 + step_idx, probs,
                                          b, prompt_len, cfg.vocab)
            new_state, metrics = vstep(carry, prompts, keys_c, band_h,
                                       band_x, pref)
            keep = {k: metrics[k] for k in ("lam", "rewards", "kl")}
            return new_state, keep

        final, ms = jax.lax.scan(body, state,
                                 (jnp.arange(k_steps), gen_keys))
        return final, ms

    return round_fn


@functools.lru_cache(maxsize=None)
def _jit_vec_round(cfg: ModelConfig, cfc: FIRMConfig, kernel: str,
                   prompt_len: int, max_new: int, length_tol: int,
                   has_pref: bool):
    """The per-round dispatch of ``_make_round_fn`` (stacked state
    donated)."""
    return jitwatch.wrap(
        f"vec_round[{kernel}]",
        jax.jit(_make_round_fn(cfg, cfc, kernel, prompt_len,
                               max_new, length_tol, has_pref),
                donate_argnums=(0,)))


@functools.lru_cache(maxsize=None)
def _jit_unstack(n: int):
    return jitwatch.wrap(
        "unstack",
        jax.jit(lambda tree: tuple(fedavg.unstack_tree(tree, n))))


_stack_trees_jit = jitwatch.wrap(
    "stack_trees", jax.jit(lambda *trees: fedavg.stack_trees(trees)))

# all C client deltas vs the broadcast anchor flattened in ONE batched
# tree op -> (C, d) f32; row c is bit-identical to tree_to_flat(delta_c)
_delta_flat_jit = jitwatch.wrap("delta_flat", jax.jit(
    lambda stacked, anchor: jnp.concatenate(
        [(a - b).astype(jnp.float32).reshape(a.shape[0], -1)
         for a, b in zip(jax.tree_util.tree_leaves(stacked),
                         jax.tree_util.tree_leaves(anchor))], axis=1)))


@functools.lru_cache(maxsize=None)
def _jit_flat_aggregate(spec):
    """Staleness-weighted FedAvg of the decoded flat deltas over the
    stacked client axis + apply to the anchor, in one dispatch (one
    unflatten total instead of one per client).  Zero staleness gives
    exactly uniform 1/C weights, so the synchronous round and the async
    scheduler's zero-staleness barrier produce bit-identical aggregates.
    """

    def fn(anchor, flats, staleness, pow):
        w = fedavg.staleness_weights(staleness, pow)
        agg = fedavg.fedavg_flat_weighted(flats, w)
        return jax.tree_util.tree_map(lambda b, d: b + d, anchor,
                                      codec_lib.flat_to_tree(agg, spec))

    return jitwatch.wrap("flat_aggregate", jax.jit(fn))


def _summary_device_fn(lams, rewards_mean, kl_mean, stacked_trainable,
                       rewards_pc):
    """All round-summary statistics computed device-side; the engine does
    ONE host transfer per round (jax.device_get of this dict)."""
    return {
        "rewards": rewards_mean,
        "lam_mean": lams.mean(0),
        "lam_disagreement": drift.lambda_disagreement(lams)["pairwise_mean"],
        "param_drift": drift.param_drift_stacked(stacked_trainable),
        "kl": kl_mean,
        "per_client_lam": lams,
        "rewards_per_client": rewards_pc,
    }


_summary_device = jitwatch.wrap("summary_device", jax.jit(_summary_device_fn))


class LocalPhaseResult(NamedTuple):
    """What every local-phase path (loop / vec / cohorts) hands back."""
    lams: jnp.ndarray                # (P, M) final per-client λ
    rewards_mean: jnp.ndarray        # (M,) mean over all client-steps
    kl_mean: jnp.ndarray             # scalar
    stacked_trainable: object        # pytree with leading (P,) client axis
    rewards_pc: jnp.ndarray          # (P, M) per-client mean over steps


class FusedCarry(NamedTuple):
    """Donated carry of the round-level ``lax.scan`` (fused_rounds path).

    Everything a round mutates rides the scan carry as arrays, so R
    rounds are ONE dispatch with zero host round-trips in between:

      states    stacked ClientState for ALL C clients (leading (C,) axis;
                critic/opt/λ/KL/step persist across rounds, trainable is
                overwritten by each round's decoded broadcast)
      ul_state  stacked traced uplink-codec state — e.g. the (C, d) error
                feedback residuals; () for stateless codecs
      dl_state  traced downlink-codec state — e.g. the DeltaCodec
                (reference reconstruction, inner state) pair
      counts    (C,) per-client prompt-stream cursors
      rng       the MAIN PRNG stream key; the body replicates run_round's
                exact split sequence (downlink key -> K x P generation
                keys step-major -> P uplink keys) for bit parity with the
                per-round path

    The server parameters are carried too but enter the jit as a
    NON-donated argument: at trainer init they alias ``ref_params``
    leaves, which must survive the call.
    """
    states: object
    ul_state: object
    dl_state: object
    counts: jnp.ndarray
    rng: jnp.ndarray


def _split_next(rng):
    """In-graph twin of ``FederatedTrainer._next_key``."""
    out = jax.random.split(rng)
    return out[0], out[1]


@functools.lru_cache(maxsize=None)
def _jit_fused_rounds(cfg: ModelConfig, cfc: FIRMConfig, kernel: str,
                      prompt_len: int, max_new: int, length_tol: int,
                      has_pref: bool, uplink_spec: str, downlink_spec: str,
                      spec, n_clients: int, n_part: int):
    """R federated rounds as ONE jitted program (round-level lax.scan).

    The scan body is a faithful in-graph transcription of ``run_round``
    on the vectorized path: participation fold-in from the named stream,
    downlink roundtrip (traced codec contract), the cohort local phase
    (``_make_round_fn``), batched delta extraction, stacked uplink
    roundtrip with carried codec state, and the weighted FedAvg
    aggregate.  Per-round summary statistics accumulate as stacked scan
    outputs — the caller does ONE host transfer per R rounds.  R itself
    stays out of this builder's cache key (jit specializes on the length
    of ``round_idxs``), so trailing partial chunks reuse the builder.
    """
    round_fn = _make_round_fn(cfg, cfc, kernel, prompt_len, max_new,
                              length_tol, has_pref)
    ul = make_codec(uplink_spec)
    dl = make_codec(downlink_spec)
    k_steps = cfc.local_steps
    full = n_part >= n_clients

    def fused(carry, global_tr, round_idxs, part_base, frozen, ref_params,
              seeds_all, probs_all, band_h_all, band_x_all, pref_all,
              extra):

        def body(c, round_idx):
            (states, g_tree, ul_state, dl_state, counts, rng) = c
            rng, dl_key = _split_next(rng)
            flat_g = jnp.concatenate(
                [l.astype(jnp.float32).reshape(-1)
                 for l in jax.tree_util.tree_leaves(g_tree)])
            bcast_flat, dl_state = dl.roundtrip_traced(flat_g, dl_state,
                                                       key=dl_key)
            broadcast = codec_lib.flat_to_tree(bcast_flat, spec)

            if full:
                idx = jnp.arange(n_clients, dtype=jnp.int32)
                seeds, probs = seeds_all, probs_all
                band_h, band_x = band_h_all, band_x_all
                pref = pref_all if has_pref else None
                counts0 = counts
                part_states = states
                ul_part = ul_state
            else:
                pk = jax.random.fold_in(part_base, round_idx)
                idx = jnp.sort(jax.random.choice(
                    pk, n_clients, (n_part,), replace=False)
                ).astype(jnp.int32)
                seeds, probs = seeds_all[idx], probs_all[idx]
                band_h, band_x = band_h_all[idx], band_x_all[idx]
                pref = pref_all[idx] if has_pref else None
                counts0 = counts[idx]
                part_states = jax.tree_util.tree_map(
                    lambda x: x[idx], states)
                ul_part = jax.tree_util.tree_map(
                    lambda x: x[idx], ul_state)

            # every participant adopts the decoded broadcast
            part_states = part_states._replace(
                trainable=jax.tree_util.tree_map(
                    lambda b: jnp.broadcast_to(b, (n_part,) + b.shape),
                    broadcast))

            # generation keys in the canonical loop order (step-major)
            gks = []
            for _k in range(k_steps):
                row = []
                for _p in range(n_part):
                    rng, kk = _split_next(rng)
                    row.append(kk)
                gks.append(jnp.stack(row))
            gen_keys = jnp.stack(gks)

            new_part, ms = round_fn(part_states, frozen, ref_params,
                                    seeds, counts0, probs, band_h,
                                    band_x, gen_keys, pref, extra)

            flat_deltas = jnp.concatenate(
                [(a - b).astype(jnp.float32).reshape(a.shape[0], -1)
                 for a, b in zip(
                     jax.tree_util.tree_leaves(new_part.trainable),
                     jax.tree_util.tree_leaves(broadcast))], axis=1)
            up_keys = []
            for _p in range(n_part):
                rng, kk = _split_next(rng)
                up_keys.append(kk)
            decoded, ul_part = ul.roundtrip_traced_stacked(
                flat_deltas, ul_part, keys=jnp.stack(up_keys))

            w = fedavg.staleness_weights(jnp.zeros(n_part, jnp.float32),
                                         jnp.float32(0.5))
            agg = fedavg.fedavg_flat_weighted(decoded, w)
            g_tree = jax.tree_util.tree_map(
                lambda b, d: b + d, broadcast,
                codec_lib.flat_to_tree(agg, spec))

            if full:
                states = new_part
                ul_state = ul_part
                counts = counts + k_steps
            else:
                states = jax.tree_util.tree_map(
                    lambda f, u: f.at[idx].set(u), states, new_part)
                ul_state = jax.tree_util.tree_map(
                    lambda f, u: f.at[idx].set(u), ul_state, ul_part)
                counts = counts.at[idx].add(k_steps)

            lams = ms["lam"][-1]                              # (P, M)
            ys = {
                # staged means match _local_phase_vectorized bit-for-bit
                # (see the comment there)
                "rewards": ms["rewards"].mean(0).mean(0),
                "lam_mean": lams.mean(0),
                "lam_disagreement":
                    drift.lambda_disagreement(lams)["pairwise_mean"],
                "param_drift":
                    drift.param_drift_stacked(new_part.trainable),
                "kl": ms["kl"].mean(0).mean(0),
                "per_client_lam": lams,
                "rewards_per_client": ms["rewards"].mean(0),
                "participants": idx,
            }
            return (states, g_tree, ul_state, dl_state, counts, rng), ys

        init = (carry.states, global_tr, carry.ul_state, carry.dl_state,
                carry.counts, carry.rng)
        (states, g_tree, ul_state, dl_state, counts, rng), ys = \
            jax.lax.scan(body, init, round_idxs)
        return (FusedCarry(states, ul_state, dl_state, counts, rng),
                g_tree, ys)

    return jitwatch.wrap(f"fused_rounds[{kernel}]",
                         jax.jit(fused, donate_argnums=(0,)))


class FederatedTrainer:
    def __init__(self, cfg: ModelConfig, fc: FIRMConfig,
                 ec: Optional[EngineConfig] = None,
                 plan: Optional["api_lib.ExecutionPlan"] = None):
        # default must be constructed per instance: a shared EngineConfig
        # default would leak mutations across trainers
        ec = EngineConfig() if ec is None else ec
        self.cfg, self.fc, self.ec = cfg, fc, ec
        # the Algorithm object owns the local-step machinery and the
        # capability declaration every path decision queries; validate
        # (fc, ec) against it before any expensive initialization
        self.algorithm = get_algorithm(ec.algorithm)
        self.algorithm.validate(fc, ec)
        key = jax.random.PRNGKey(ec.seed)
        self.params = transformer.init_params(cfg, key)
        trainable, frozen = split_trainable(self.params)
        self.frozen = frozen
        self.ref_params = self.params                     # frozen reference
        self.global_trainable = trainable
        self.client_states = [
            local_lib.init_client_state(trainable, fc.n_objectives,
                                        cfg.d_model, fc.kl_coef_init)
            for _ in range(fc.n_clients)]
        self.datasets = make_client_datasets(
            fc.n_clients, cfg.vocab, ec.prompt_len,
            alpha=ec.dirichlet_alpha, seed=ec.seed)
        # static per-client sampler inputs, cached for the vmapped block
        # sampler (only the per-client counts change between rounds)
        self._seeds_all = jnp.asarray([ds.seed for ds in self.datasets],
                                      jnp.int32)
        self._probs_all = jnp.stack([ds.topic_probs
                                     for ds in self.datasets])
        # shared TreeSpec of the per-client delta (the uplink's flat
        # Payload boundary)
        leaves, treedef = jax.tree_util.tree_flatten(trainable)
        self._delta_spec = codec_lib.TreeSpec(
            treedef, tuple(l.shape for l in leaves),
            tuple(l.dtype for l in leaves))
        self._length_tol = max(4, ec.max_new // 2)
        self.reward_fns = []
        bands = []
        for c in range(fc.n_clients):
            variant = ("alt" if ec.heterogeneous_rms and
                       c >= fc.n_clients // 2 else "default")
            self.reward_fns.append(rewards_lib.make_reward_fns(
                cfg.vocab, fc.n_objectives, variant=variant,
                length_tolerance=self._length_tol))
            bands.append(rewards_lib.variant_bands(cfg.vocab, variant))
        # per-client reward-band parameters, stacked for the vmapped scorer
        self._bands_h = jnp.stack([bh for bh, _ in bands])
        self._bands_x = jnp.stack([bx for _, bx in bands])
        self.ledger = comms.CommsLedger()
        # comms codecs: one stateless codec per link; per-client error
        # feedback residuals stay in client-indexed slots here
        self.uplink_codec = make_codec(ec.uplink_codec)
        self.downlink_codec = make_codec(ec.downlink_codec)
        self._uplink_state = [None] * fc.n_clients
        self._downlink_state = None
        self.d_trainable = tree_size(trainable)
        self.history: List[dict] = []
        self._rng = jax.random.PRNGKey(ec.seed + 1)
        # named PRNG stream for participation sampling: keyed on
        # (seed, round index) only, never on how many keys the main
        # stream consumed — deadline over-selection and dropout in the
        # scheduler reproduce the same client draws across policies
        self._part_rng_base = jax.random.fold_in(
            jax.random.PRNGKey(ec.seed + 1), 0x5ced)
        self._round_idx = 0
        # per-client configs expanded through the algorithm (pluralistic
        # preferences, FedMOA-style heterogeneous local-step rates)
        self._client_fcs = client_configs(self.algorithm, fc)
        self._jit_steps = [self.algorithm.local_step_fn(cfg, cfc)
                           for cfc in self._client_fcs]
        self._jit_ref_lp = partial(_jit_ref_logprobs(cfg), self.ref_params)
        self._stacked_pref = (
            jnp.asarray(fc.client_preferences, jnp.float32)
            if fc.client_preferences is not None else None)
        # engine-level jitted dispatch counter (round_throughput benchmark)
        self.jit_dispatches = 0
        # engine-owned device->host summary transfers: ONE per round on
        # the per-round paths, ONE per chunk on the fused path (the plan
        # auditor and the obs overhead test read this)
        self.host_transfers = 0
        # telemetry write path: every round summary fans out through
        # this pipeline (EngineConfig.metrics_sink names extra sinks;
        # an in-memory sink is always attached)
        self.obs = MetricsPipeline.from_spec(ec.metrics_sink)
        # last round's uplink payloads (per-round path only; offline
        # payload analysis, e.g. entropy estimates in codec_tradeoff)
        self._last_up_payloads: List = []
        # the declarative mirror of this trainer's path decisions; built
        # through the same capability resolution the methods below use
        self.plan = plan if plan is not None else api_lib.plan(
            api_lib.RunSpec(model=cfg, firm=fc, engine=ec),
            d_trainable=self.d_trainable)

    # ------------------------------------------------------------------
    def _fc_for_algorithm(self) -> FIRMConfig:
        return self.algorithm.resolve_config(self.fc)

    def _next_key(self):
        self._rng, k = jax.random.split(self._rng)
        return k

    def _make_batch(self, c: int) -> ppo.PPOBatch:
        prompts = self.datasets[c].next_batch(self.fc.batch_size)
        params = merge_trainable(self.client_states[c].trainable,
                                 self.frozen)
        tokens, old_lp, mask = generate(self.cfg, params, prompts,
                                        self._next_key(),
                                        max_new=self.ec.max_new)
        self.jit_dispatches += 1
        r = rewards_lib.score_batch(self.reward_fns[c], tokens, mask)
        ref_lp = self._jit_ref_lp(tokens)
        self.jit_dispatches += 1
        return ppo.PPOBatch(tokens, mask, old_lp, ref_lp, r)

    # ------------------------------------------------------------------
    def _participation_key(self, round_idx: Optional[int] = None):
        r = self._round_idx if round_idx is None else round_idx
        return jax.random.fold_in(self._part_rng_base, r)

    def _sample_participants(self, n: Optional[int] = None,
                             round_idx: Optional[int] = None) -> List[int]:
        """Draw this round's participants from the named stream.

        ``n`` overrides the participation-derived count (the deadline
        policy over-selects); same (seed, round) -> same draw no matter
        what else consumed PRNG keys in between.
        """
        fc = self.fc
        if n is None:
            n = max(1, int(round(fc.participation * fc.n_clients)))
        if n >= fc.n_clients:
            return list(range(fc.n_clients))
        idx = jax.random.choice(self._participation_key(round_idx),
                                fc.n_clients, (n,), replace=False)
        return sorted(int(i) for i in idx)

    def _local_phase_mode(self, participants: List[int]):
        """Pick the round's local-phase path: ("vec"|"cohort"|"loop", plan).

        Pure capability resolution — see ``api.resolve_local_mode`` for
        the rules (shared with the plan-time front door).
        """
        mode, plan, _ = api_lib.resolve_local_mode(
            self.algorithm, self._client_fcs, participants,
            vectorized_clients=self.ec.vectorized_clients,
            lift_preference=self._stacked_pref is not None)
        return mode, plan

    def _use_vectorized(self) -> bool:
        """Back-compat probe: does any vmapped path serve a full round?"""
        mode, _ = self._local_phase_mode(list(range(self.fc.n_clients)))
        return mode != "loop"

    def _fused_mode(self):
        """(eligible, cohort cfc) for the fused multi-round program —
        ``api.resolve_fused`` over the full population's local mode."""
        mode, plan = self._local_phase_mode(list(range(self.fc.n_clients)))
        ok, _ = api_lib.resolve_fused(self.algorithm, mode,
                                      self.uplink_codec,
                                      self.downlink_codec)
        if not ok:
            return False, None
        return True, plan[0].cfc

    # ------------------------------------------------------------------
    def _aggregate_flat(self, anchor, flats, staleness,
                        staleness_pow: float = 0.5):
        """(anchor tree, (C, d) decoded deltas, (C,) staleness) -> new
        params; the single server-side aggregation dispatch.  The async
        scheduler calls this directly with nonzero staleness."""
        out = _jit_flat_aggregate(self._delta_spec)(
            anchor, flats, jnp.asarray(staleness, jnp.float32),
            jnp.float32(staleness_pow))
        self.jit_dispatches += 1
        return out

    def run_round(self, participants: Optional[List[int]] = None) -> dict:
        fc = self._fc_for_algorithm()
        if participants is None:
            participants = self._sample_participants()
        round_idx = self._round_idx
        dispatch0 = self.jit_dispatches
        # broadcast θ_t through the downlink codec; every client receives
        # (and trains from) the same decoded broadcast
        dl_payload, self._downlink_state, broadcast = \
            self.downlink_codec.roundtrip(
                self.global_trainable, self._downlink_state,
                key=self._next_key())
        for c in participants:
            self.ledger.send_down(dl_payload)

        mode, plan = self._local_phase_mode(participants)
        if mode == "vec":
            # the cohort's shared config, not the base fc: a UNIFORM
            # client_local_steps override still forms one cohort but its
            # K differs from fc.local_steps
            res = self._local_phase_vectorized(plan[0].cfc, participants,
                                               broadcast)
        elif mode == "cohort":
            res = self._local_phase_cohorts(plan, participants, broadcast)
        else:
            res = self._local_phase_loop(fc, participants, broadcast)

        # participating clients transmit adapted-param deltas through the
        # uplink codec (residuals stay client-local); the delta against
        # the broadcast anchor flattens in one batched tree op over the
        # stacked axis, the codec encodes all clients at the stacked
        # (flat) Payload boundary — one batched kernel dispatch for
        # quantize codecs — and the server aggregates the decoded (C, d)
        # matrix in one matvec + single unflatten
        flat_deltas = _delta_flat_jit(res.stacked_trainable, broadcast)
        self.jit_dispatches += 1
        up_keys = [self._next_key() for _ in participants]
        payloads, new_states, decoded = self.uplink_codec.roundtrip_stacked(
            flat_deltas, self._delta_spec,
            [self._uplink_state[c] for c in participants], keys=up_keys)
        for ci, c in enumerate(participants):
            self._uplink_state[c] = new_states[ci]
            self.ledger.send_up(payloads[ci])
        # kept for offline payload analysis (entropy-coded size estimates
        # in benchmarks/codec_tradeoff.py) — references only, no copies
        self._last_up_payloads = payloads
        self.global_trainable = self._aggregate_flat(
            broadcast, decoded, jnp.zeros(len(participants), jnp.float32))
        self.ledger.next_round()
        self._round_idx += 1

        # metrics were accumulated on device; ONE host transfer per round
        stats = _summary_device(res.lams, res.rewards_mean, res.kl_mean,
                                res.stacked_trainable, res.rewards_pc)
        self.jit_dispatches += 1
        host = jax.device_get(stats)
        self.host_transfers += 1
        summary = obs_records.round_summary(
            stats=host,
            comm_bytes=self.ledger.total,
            up_bytes=self.ledger.up_bytes,
            down_bytes=self.ledger.down_bytes,
            participants=participants,
            dispatches=self.jit_dispatches - dispatch0,
            # per-client wire/work facts the scheduler's time model reads
            up_nbytes=[int(p.nbytes) for p in payloads],
            down_nbytes=comms.measured_bytes(dl_payload),
            local_steps=[self._client_fcs[c].local_steps
                         for c in participants],
            cohorts=len(plan) if plan is not None else 0,
        )
        self.history.append(summary)
        self.obs.emit_round(summary, round=round_idx)
        return summary

    # ------------------------------------------------- fused rounds path
    def run_rounds_fused(self, rounds: int) -> List[dict]:
        """R rounds as ONE jitted dispatch + ONE host transfer.

        See ``FusedCarry`` for the scan-carry layout and
        ``_jit_fused_rounds`` for the round body.  Byte accounting uses
        the codecs' exact ``nbytes_static`` sizes (no payloads are
        materialized), and the per-round summaries match ``run_round``'s
        except that ``dispatches`` is the chunk total amortized per round
        and a ``fused`` key records the chunk length.
        """
        ok, cfc = self._fused_mode()
        if not ok:
            raise ValueError(
                "fused_rounds requires a fusable algorithm (traced server "
                "exchange, vmap-safe local step), one full-population "
                "static-config cohort, and codecs supporting the traced "
                "contract; use run()/run_round() instead")
        fc = self.fc
        c_all = fc.n_clients
        n_part = min(c_all, max(1, int(round(fc.participation * c_all))))
        has_pref = self._stacked_pref is not None
        cfc_t = (dataclasses.replace(cfc, preference=None)
                 if has_pref else cfc)
        extra = self.algorithm.traced_extra(cfc, self.ec)
        d = self.d_trainable
        dispatch0 = self.jit_dispatches

        # stacking copies every per-client buffer, so the donated carry
        # never aliases live host state (client_states / ref_params)
        stacked_states = _stack_trees_jit(*self.client_states)
        self.jit_dispatches += 1
        carry = FusedCarry(
            states=stacked_states,
            ul_state=self.uplink_codec.init_states_traced(
                d, self._uplink_state),
            dl_state=self.downlink_codec.init_state_traced(
                d, self._downlink_state),
            counts=jnp.asarray([ds._count for ds in self.datasets],
                               jnp.int32),
            rng=self._rng)
        round_idxs = jnp.arange(self._round_idx, self._round_idx + rounds,
                                dtype=jnp.int32)
        fn = _jit_fused_rounds(self.cfg, cfc_t, self.algorithm.kernel,
                               self.ec.prompt_len, self.ec.max_new,
                               self._length_tol, has_pref,
                               self.ec.uplink_codec, self.ec.downlink_codec,
                               self._delta_spec, c_all, n_part)
        carry, new_global, ys = fn(
            carry, self.global_trainable, round_idxs, self._part_rng_base,
            self.frozen, self.ref_params, self._seeds_all, self._probs_all,
            self._bands_h, self._bands_x, self._stacked_pref, extra)
        self.jit_dispatches += 1

        # ONE host transfer for the whole chunk's metrics
        host = jax.device_get({"ys": ys, "counts": carry.counts})
        self.host_transfers += 1
        self.client_states = list(_jit_unstack(c_all)(carry.states))
        self.jit_dispatches += 1
        self.global_trainable = new_global
        self._uplink_state = self.uplink_codec.states_to_host(
            carry.ul_state, c_all)
        self._downlink_state = self.downlink_codec.state_to_host(
            carry.dl_state)
        self._rng = carry.rng
        for ci, ds in enumerate(self.datasets):
            ds._count = int(host["counts"][ci])
        self._round_idx += rounds

        up_static = self.uplink_codec.nbytes_static(d)
        down_static = self.downlink_codec.nbytes_static(d)
        per_round_dispatches = (self.jit_dispatches - dispatch0) / rounds
        ys_h = host["ys"]
        round0 = self._round_idx - rounds
        out = []
        for r in range(rounds):
            parts = [int(x) for x in ys_h["participants"][r]]
            p = len(parts)
            self.ledger.down_bytes += p * down_static
            self.ledger.up_bytes += p * up_static
            self.ledger.next_round()
            # per-round records derive from the chunk's stacked scan
            # outputs + static plan bytes: zero additional host syncs
            summary = obs_records.round_summary(
                stats={k: ys_h[k][r] for k in
                       ("rewards", "lam_mean", "lam_disagreement",
                        "param_drift", "kl", "per_client_lam",
                        "rewards_per_client")},
                comm_bytes=self.ledger.total,
                up_bytes=self.ledger.up_bytes,
                down_bytes=self.ledger.down_bytes,
                participants=parts,
                dispatches=per_round_dispatches,
                up_nbytes=[up_static] * p,
                down_nbytes=down_static,
                local_steps=[cfc.local_steps] * p,
                cohorts=1,
                fused=rounds,
            )
            out.append(summary)
            self.history.append(summary)
            self.obs.emit_round(summary, round=round0 + r)
        return out

    # ------------------------------------------------- per-client loop path
    def _local_phase_loop(self, fc: FIRMConfig, participants: List[int],
                          broadcast):
        # the jitted local step donates its state argument, so every
        # participant must OWN its trainable buffers: adopt the broadcast
        # by copy, never by alias (the anchor must survive for the delta,
        # and clients must not share donated buffers)
        for c in participants:
            self.client_states[c] = self.client_states[c]._replace(
                trainable=jax.tree_util.tree_map(jnp.copy, broadcast))
        # the algorithm owns the loop body (step order, exchanges, the
        # per-entry metric dicts); the engine owns the common accounting
        round_metrics = self.algorithm.loop_phase(self, fc, participants)

        # metrics stay device-resident: stack on device, convert to host
        # once per round in run_round's summary
        last_lam = {m["client"]: m["lam"] for m in round_metrics
                    if "lam" in m}
        lams = jnp.stack([last_lam[c] for c in participants])
        rewards_mean = jnp.stack([m["rewards"]
                                  for m in round_metrics]).mean(0)
        kl_mean = jnp.stack([m["kl"] for m in round_metrics]).mean()
        rewards_pc = jnp.stack([
            jnp.stack([m["rewards"] for m in round_metrics
                       if m["client"] == c]).mean(0) for c in participants])
        stacked_tr = _stack_trees_jit(
            *[self.client_states[c].trainable for c in participants])
        self.jit_dispatches += 1
        return LocalPhaseResult(lams, rewards_mean, kl_mean, stacked_tr,
                                rewards_pc)

    # ------------------------------------------------- vectorized path
    def _local_phase_vectorized(self, fc: FIRMConfig,
                                participants: List[int], broadcast,
                                gen_keys=None) -> "LocalPhaseResult":
        """One cohort's local phase as a single scanned/vmapped dispatch.

        Every participant starts from the shared ``broadcast`` (each
        dispatch in the async scheduler uses one version, too).
        ``gen_keys`` optionally supplies pre-drawn (K, C, 2) generation
        keys — the multi-cohort dispatch draws them in the canonical
        loop order across ALL participants and slices per cohort.
        """
        p_count = len(participants)
        k_steps = fc.local_steps
        m = fc.n_objectives
        has_pref = self._stacked_pref is not None
        cfc = dataclasses.replace(fc, preference=None) if has_pref else fc

        counts0 = jnp.asarray([self.datasets[c]._count
                               for c in participants], jnp.int32)
        if p_count == self.fc.n_clients:     # full participation: cached
            seeds, probs = self._seeds_all, self._probs_all
            band_h, band_x = self._bands_h, self._bands_x
            pref = self._stacked_pref if has_pref else None
        else:
            idx = jnp.asarray(participants, jnp.int32)
            seeds, probs = self._seeds_all[idx], self._probs_all[idx]
            band_h, band_x = self._bands_h[idx], self._bands_x[idx]
            pref = self._stacked_pref[idx] if has_pref else None
        # advance the per-client prompt streams exactly as the loop would
        for c in participants:
            self.datasets[c]._count += k_steps

        # stacking copies the broadcast into a fresh (C, ...) buffer, so
        # the stacked state is safe to donate and the anchor survives
        states = [self.client_states[c]._replace(trainable=broadcast)
                  for c in participants]
        stacked = _stack_trees_jit(*states)
        self.jit_dispatches += 1

        if not self.algorithm.caps.traced_server_exchange:
            # host-driven server exchange: the algorithm owns the phase
            # (jitted client phases around its host exchange)
            lams, rewards_mean, kl_mean, rewards_pc, stacked = \
                self.algorithm.exchange_phase_vectorized(
                    self, cfc, participants, stacked, seeds, counts0,
                    probs, band_h, band_x)
        else:
            if gen_keys is None:
                # per-client generation keys, drawn in the loop path's
                # order (step-major, then participant order) for exact
                # key parity
                gen_keys = jnp.stack(
                    [jnp.stack([self._next_key() for _ in participants])
                     for _ in range(k_steps)])
            extra = self.algorithm.traced_extra(cfc, self.ec)
            fn = _jit_vec_round(self.cfg, cfc, self.algorithm.kernel,
                                self.ec.prompt_len, self.ec.max_new,
                                self._length_tol, has_pref)
            stacked, ms = fn(stacked, self.frozen, self.ref_params, seeds,
                             counts0, probs, band_h, band_x, gen_keys,
                             pref, extra)
            self.jit_dispatches += 1
            lams = ms["lam"][-1]                              # (C, M)
            # one axis at a time: a flat (K*C) mean is emitted as a
            # multi-dim reduce whose association differs between this
            # eager context and the fused round scan; staged means are
            # context-stable, keeping the two paths bit-identical
            rewards_mean = ms["rewards"].mean(0).mean(0)
            kl_mean = ms["kl"].mean(0).mean(0)
            rewards_pc = ms["rewards"].mean(0)                # (C, M)

        new_states = _jit_unstack(p_count)(stacked)
        self.jit_dispatches += 1
        for ci, c in enumerate(participants):
            self.client_states[c] = new_states[ci]
        return LocalPhaseResult(lams, rewards_mean, kl_mean,
                                stacked.trainable, rewards_pc)

    # ------------------------------------------------- cohort dispatch
    def _local_phase_cohorts(self, plan, participants: List[int],
                             broadcast) -> "LocalPhaseResult":
        """Group-by-config dispatch: one vmapped program per cohort.

        Generation keys are drawn ONCE in the canonical loop order —
        step-major over all participants, skipping clients whose K is
        exhausted — then sliced per cohort, so a multi-cohort round
        consumes the PRNG stream exactly like the per-client loop and
        stays equivalent to it.  Per-cohort results reassemble into
        participant order; scalar metrics merge weighted by each
        cohort's client-step count (n_g * K_g), matching the loop's
        mean-over-entries semantics.
        """
        steps = {c: self._client_fcs[c].local_steps for c in participants}
        keys = {}
        for k in range(max(steps.values())):
            for c in participants:
                if k < steps[c]:
                    keys[(c, k)] = self._next_key()

        pos = {c: i for i, c in enumerate(participants)}
        lam_rows = [None] * len(participants)
        rpc_rows = [None] * len(participants)
        stacked_parts, order = [], []
        rew_acc, kl_acc, w_tot = 0.0, 0.0, 0
        for co in plan:
            members = list(co.members)
            gk = jnp.stack(
                [jnp.stack([keys[(c, k)] for c in members])
                 for k in range(co.cfc.local_steps)])
            res = self._local_phase_vectorized(co.cfc, members, broadcast,
                                               gen_keys=gk)
            for i, c in enumerate(members):
                lam_rows[pos[c]] = res.lams[i]
                rpc_rows[pos[c]] = res.rewards_pc[i]
            w = len(members) * co.cfc.local_steps
            rew_acc = rew_acc + w * res.rewards_mean
            kl_acc = kl_acc + w * res.kl_mean
            w_tot += w
            stacked_parts.append(res.stacked_trainable)
            order.extend(members)

        inv = jnp.asarray([order.index(c) for c in participants], jnp.int32)
        stacked_tr = jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs, axis=0)[inv], *stacked_parts)
        self.jit_dispatches += 1
        return LocalPhaseResult(jnp.stack(lam_rows), rew_acc / w_tot,
                                kl_acc / w_tot, stacked_tr,
                                jnp.stack(rpc_rows))

    def run(self, rounds: Optional[int] = None) -> List[dict]:
        total = rounds or self.fc.rounds
        chunk = max(1, int(self.ec.fused_rounds))
        if chunk > 1 and self._fused_mode()[0]:
            left = total
            while left > 0:
                r = min(chunk, left)
                if r == 1:
                    self.run_round()
                else:
                    self.run_rounds_fused(r)
                left -= r
        else:
            for _ in range(total):
                self.run_round()
        return self.history
