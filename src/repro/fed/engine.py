"""Federated alignment simulation engine (paper §5 experimental loop).

Simulates the server + C clients protocol end-to-end at laptop scale:
generation with the current local policy, synthetic reward scoring, the
FIRM (or baseline) local update, FedAvg aggregation, and full metric /
communication accounting.  Algorithms:

  'firm'       — paper Alg. 1 (in-client regularized MGDA)
  'firm_unreg' — β = 0 ablation (RQ2)
  'fedcmoo'    — server-centric MGDA baseline (RQ1, Askin et al. 2024)
  'linear'     — fixed-weight linear scalarization (implicit baseline)

All uplink/downlink traffic flows through the repro.comms codec layer
(EngineConfig.uplink_codec / downlink_codec registry specs): clients
upload encoded *deltas* against the decoded broadcast they trained from,
error-feedback residuals stay client-local, and the ledger records the
measured Payload bytes (int8 uplink ≈ 1/4 of raw f32).

Round execution (vectorized round engine)
-----------------------------------------
Two interchangeable local-phase paths:

* **vectorized** (default, ``EngineConfig.vectorized_clients``):
  participant ``ClientState``s are held as ONE pytree with a leading
  client axis; prompt sampling (``data.partition.sample_prompt_block``),
  rollout generation, reward scoring (banded, per-client parameters),
  reference logprobs and the local update are all ``jax.vmap``ed over
  that axis, and the K local steps run under one ``jax.lax.scan`` — the
  entire local phase is a single jitted dispatch with the stacked state
  donated.  Per-step metrics (stacked λ / KL / rewards) stay
  device-resident and transfer to host once per round.  The client→server
  delta and FedAvg are single batched tree ops over the stacked axis.
* **per-client loop**: the original Python loop (C × K dispatches), kept
  for equivalence testing and as the fallback when per-client configs
  diverge statically.

vmap groups clients by IDENTICAL static config: every participant must
share one ``FIRMConfig`` once ``preference`` is lifted to a traced
(C, M) array (``client_preferences`` all set, or none) — any other
per-client static divergence (e.g. mixed solvers) falls back to the
loop path.  The comms codec stays per-client at the Payload boundary in
both paths; vmapping the codec encode itself is a recorded follow-up.
"""
from __future__ import annotations

import dataclasses
import functools
from functools import partial
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.comms import ErrorFeedback, make_codec
from repro.comms import codec as codec_lib
from repro.configs.base import FIRMConfig, ModelConfig
from repro.core import comms, drift, fedavg, fedcmoo
from repro.data.partition import make_client_datasets, sample_prompt_block
from repro.models import transformer
from repro.models.common import merge_trainable, split_trainable, tree_size
from repro.rlhf import local as local_lib
from repro.rlhf import ppo, rewards as rewards_lib
from repro.rlhf.sampling import generate


# Jitted callables are memoized on the (hashable, frozen) configs so every
# trainer with the same architecture + FIRM hyperparameters shares one
# trace/compile per process — the test suite and benchmark sweeps build
# dozens of identically-configured trainers.
@functools.lru_cache(maxsize=None)
def _jit_local_step(cfg: ModelConfig, cfc: FIRMConfig):
    # the client-state argument is donated: its buffers are reused for the
    # updated state in place.  Callers must pass states whose buffers are
    # not aliased elsewhere (the engine adopts the broadcast by copy).
    return jax.jit(partial(local_lib.firm_local_step, cfg, cfc),
                   donate_argnums=(0,))


@functools.lru_cache(maxsize=None)
def _jit_ref_logprobs(cfg: ModelConfig):
    def ref_lp(ref_params, tokens):
        out = transformer.forward_seq(cfg, ref_params, tokens)
        return ppo.token_logprobs(out["logits"], tokens)
    return jax.jit(ref_lp)


@functools.lru_cache(maxsize=None)
def _jit_sample_block(batch_size: int, prompt_len: int, vocab: int):
    return jax.jit(lambda seeds, counts, probs: sample_prompt_block(
        seeds, counts, probs, batch_size, prompt_len, vocab))


@functools.lru_cache(maxsize=None)
def _jit_vec_round(cfg: ModelConfig, cfc: FIRMConfig, algorithm: str,
                   prompt_len: int, max_new: int, length_tol: int,
                   has_pref: bool):
    """One round's entire local phase as a single jitted program.

    vmap over the stacked client axis x lax.scan over the K local steps:
    sampling, generation, reward scoring, reference logprobs and the
    local update all fuse into one dispatch.  The stacked client state
    (arg 0) is donated.
    """
    k_steps = cfc.local_steps
    m = cfc.n_objectives
    b = cfc.batch_size

    def round_fn(state, frozen, ref_params, seeds, counts0, probs,
                 band_h, band_x, gen_keys, pref, lin_w):

        def one_client(st, prompts, key, bh, bx, p):
            params = merge_trainable(st.trainable, frozen)
            tokens, old_lp, mask = generate(cfg, params, prompts, key,
                                            max_new=max_new)
            r = rewards_lib.score_batch_banded(bh, bx, tokens, mask, m,
                                               length_tol)
            ref_out = transformer.forward_seq(cfg, ref_params, tokens)
            ref_lp = ppo.token_logprobs(ref_out["logits"], tokens)
            batch = ppo.PPOBatch(tokens, mask, old_lp, ref_lp, r)
            if algorithm == "linear":
                return local_lib.linear_local_step(cfg, cfc, st, frozen,
                                                   batch, lin_w)
            return local_lib.firm_local_step(cfg, cfc, st, frozen, batch,
                                             preference=p)

        vstep = jax.vmap(one_client,
                         in_axes=(0, 0, 0, 0, 0, 0 if has_pref else None))

        def body(carry, xs):
            step_idx, keys_c = xs
            prompts = sample_prompt_block(seeds, counts0 + step_idx, probs,
                                          b, prompt_len, cfg.vocab)
            new_state, metrics = vstep(carry, prompts, keys_c, band_h,
                                       band_x, pref)
            keep = {k: metrics[k] for k in ("lam", "rewards", "kl")}
            return new_state, keep

        final, ms = jax.lax.scan(body, state,
                                 (jnp.arange(k_steps), gen_keys))
        return final, ms

    return jax.jit(round_fn, donate_argnums=(0,))


@functools.lru_cache(maxsize=None)
def _jit_vec_fedcmoo_grads(cfg: ModelConfig, cfc: FIRMConfig, max_new: int,
                           length_tol: int):
    """FedCMOO client phase 1, vmapped: rollouts + M gradients for every
    participant in one dispatch.  Gradients return stacked so the server
    exchange (per-client codec Payloads + one λ solve) stays at the host
    boundary between the two jitted phases."""
    m = cfc.n_objectives

    def fn(state, frozen, ref_params, prompts, keys, band_h, band_x):
        def one(st, pr, key, bh, bx):
            params = merge_trainable(st.trainable, frozen)
            tokens, old_lp, mask = generate(cfg, params, pr, key,
                                            max_new=max_new)
            r = rewards_lib.score_batch_banded(bh, bx, tokens, mask, m,
                                               length_tol)
            ref_out = transformer.forward_seq(cfg, ref_params, tokens)
            ref_lp = ppo.token_logprobs(ref_out["logits"], tokens)
            batch = ppo.PPOBatch(tokens, mask, old_lp, ref_lp, r)
            grads, losses, extras = local_lib.fedcmoo_local_grads(
                cfg, cfc, st, frozen, batch)
            return grads, extras, batch.rewards.mean(0)

        return jax.vmap(one)(state, prompts, keys, band_h, band_x)

    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def _jit_vec_fedcmoo_apply(cfc: FIRMConfig):
    """FedCMOO client phase 2, vmapped, with the stacked state donated."""

    def fn(state, grads, lam, extras):
        def one(st, g, e):
            return local_lib.fedcmoo_local_apply(cfc, st, g, lam, e)

        return jax.vmap(one)(state, grads, extras)

    return jax.jit(fn, donate_argnums=(0,))


@functools.lru_cache(maxsize=None)
def _jit_unstack(n: int):
    return jax.jit(lambda tree: tuple(fedavg.unstack_tree(tree, n)))


_stack_trees_jit = jax.jit(lambda *trees: fedavg.stack_trees(trees))

# all C client deltas vs the broadcast anchor flattened in ONE batched
# tree op -> (C, d) f32; row c is bit-identical to tree_to_flat(delta_c)
_delta_flat_jit = jax.jit(lambda stacked, anchor: jnp.concatenate(
    [(a - b).astype(jnp.float32).reshape(a.shape[0], -1)
     for a, b in zip(jax.tree_util.tree_leaves(stacked),
                     jax.tree_util.tree_leaves(anchor))], axis=1))


@functools.lru_cache(maxsize=None)
def _jit_flat_aggregate(spec):
    """FedAvg of the decoded flat deltas over the stacked client axis +
    apply to the broadcast anchor, in one dispatch (one unflatten total
    instead of one per client)."""

    def fn(anchor, *flats):
        mean = fedavg.fedavg_stacked(jnp.stack(flats))
        return jax.tree_util.tree_map(lambda b, d: b + d, anchor,
                                      codec_lib.flat_to_tree(mean, spec))

    return jax.jit(fn)


@jax.jit
def _summary_device(lams, rewards_mean, kl_mean, stacked_trainable):
    """All round-summary statistics computed device-side; the engine does
    ONE host transfer per round (jax.device_get of this dict)."""
    return {
        "rewards": rewards_mean,
        "lam_mean": lams.mean(0),
        "lam_disagreement": drift.lambda_disagreement(lams)["pairwise_mean"],
        "param_drift": drift.param_drift_stacked(stacked_trainable),
        "kl": kl_mean,
        "per_client_lam": lams,
    }


@dataclasses.dataclass
class EngineConfig:
    algorithm: str = "firm"
    prompt_len: int = 8
    max_new: int = 24
    dirichlet_alpha: float = 0.3
    seed: int = 0
    heterogeneous_rms: bool = False      # half the clients use the alt RM
    fedcmoo_compress_rank: Optional[int] = None
    linear_weights: Optional[Sequence[float]] = None
    # comms codecs (repro.comms registry specs, e.g. "int8+ef")
    uplink_codec: str = "identity"       # client -> server deltas/grads
    downlink_codec: str = "identity"     # server -> client broadcast
    # run the round's local phase as one vmapped/scanned jit over the
    # stacked client axis (falls back to the per-client loop when
    # per-client static configs diverge; see module docstring)
    vectorized_clients: bool = True


class FederatedTrainer:
    def __init__(self, cfg: ModelConfig, fc: FIRMConfig,
                 ec: Optional[EngineConfig] = None):
        # default must be constructed per instance: a shared EngineConfig
        # default would leak mutations across trainers
        ec = EngineConfig() if ec is None else ec
        self.cfg, self.fc, self.ec = cfg, fc, ec
        key = jax.random.PRNGKey(ec.seed)
        self.params = transformer.init_params(cfg, key)
        trainable, frozen = split_trainable(self.params)
        self.frozen = frozen
        self.ref_params = self.params                     # frozen reference
        self.global_trainable = trainable
        self.client_states = [
            local_lib.init_client_state(trainable, fc.n_objectives,
                                        cfg.d_model, fc.kl_coef_init)
            for _ in range(fc.n_clients)]
        self.datasets = make_client_datasets(
            fc.n_clients, cfg.vocab, ec.prompt_len,
            alpha=ec.dirichlet_alpha, seed=ec.seed)
        # static per-client sampler inputs, cached for the vmapped block
        # sampler (only the per-client counts change between rounds)
        self._seeds_all = jnp.asarray([ds.seed for ds in self.datasets],
                                      jnp.int32)
        self._probs_all = jnp.stack([ds.topic_probs
                                     for ds in self.datasets])
        # shared TreeSpec of the per-client delta (the uplink's flat
        # Payload boundary)
        leaves, treedef = jax.tree_util.tree_flatten(trainable)
        self._delta_spec = codec_lib.TreeSpec(
            treedef, tuple(l.shape for l in leaves),
            tuple(l.dtype for l in leaves))
        self._length_tol = max(4, ec.max_new // 2)
        self.reward_fns = []
        bands = []
        for c in range(fc.n_clients):
            variant = ("alt" if ec.heterogeneous_rms and
                       c >= fc.n_clients // 2 else "default")
            self.reward_fns.append(rewards_lib.make_reward_fns(
                cfg.vocab, fc.n_objectives, variant=variant,
                length_tolerance=self._length_tol))
            bands.append(rewards_lib.variant_bands(cfg.vocab, variant))
        # per-client reward-band parameters, stacked for the vmapped scorer
        self._bands_h = jnp.stack([bh for bh, _ in bands])
        self._bands_x = jnp.stack([bx for _, bx in bands])
        self.ledger = comms.CommsLedger()
        # comms codecs: one stateless codec per link; per-client error
        # feedback residuals stay in client-indexed slots here
        self.uplink_codec = make_codec(ec.uplink_codec)
        self.downlink_codec = make_codec(ec.downlink_codec)
        self._uplink_state = [None] * fc.n_clients
        self._downlink_state = None
        self.d_trainable = tree_size(trainable)
        self.history: List[dict] = []
        self._rng = jax.random.PRNGKey(ec.seed + 1)
        # per-client FIRM configs (pluralistic preferences, §6 future work)
        self._client_fcs = []
        base_fc = self._fc_for_algorithm()
        for c in range(fc.n_clients):
            cfc = base_fc
            if fc.client_preferences is not None:
                cfc = dataclasses.replace(
                    base_fc, preference=fc.client_preferences[c])
            self._client_fcs.append(cfc)
        self._jit_steps = [_jit_local_step(cfg, cfc)
                           for cfc in self._client_fcs]
        self._jit_ref_lp = partial(_jit_ref_logprobs(cfg), self.ref_params)
        self._stacked_pref = (
            jnp.asarray(fc.client_preferences, jnp.float32)
            if fc.client_preferences is not None else None)
        # engine-level jitted dispatch counter (round_throughput benchmark)
        self.jit_dispatches = 0

    # ------------------------------------------------------------------
    def _fc_for_algorithm(self) -> FIRMConfig:
        fc = self.fc
        if self.ec.algorithm == "firm_unreg":
            fc = dataclasses.replace(fc, beta=0.0)
        return fc

    def _next_key(self):
        self._rng, k = jax.random.split(self._rng)
        return k

    def _make_batch(self, c: int) -> ppo.PPOBatch:
        prompts = self.datasets[c].next_batch(self.fc.batch_size)
        params = merge_trainable(self.client_states[c].trainable,
                                 self.frozen)
        tokens, old_lp, mask = generate(self.cfg, params, prompts,
                                        self._next_key(),
                                        max_new=self.ec.max_new)
        self.jit_dispatches += 1
        r = rewards_lib.score_batch(self.reward_fns[c], tokens, mask)
        ref_lp = self._jit_ref_lp(tokens)
        self.jit_dispatches += 1
        return ppo.PPOBatch(tokens, mask, old_lp, ref_lp, r)

    # ------------------------------------------------------------------
    def _sample_participants(self) -> List[int]:
        fc = self.fc
        n = max(1, int(round(fc.participation * fc.n_clients)))
        if n >= fc.n_clients:
            return list(range(fc.n_clients))
        idx = jax.random.choice(self._next_key(), fc.n_clients, (n,),
                                replace=False)
        return sorted(int(i) for i in idx)

    def _grad_codec(self):
        """Codec for per-step gradient uploads (fedcmoo/linear): error
        feedback is defined per client *stream*, not per objective, so the
        M parallel gradient trees use the EF-stripped inner codec."""
        ul = self.uplink_codec
        return ul.inner if isinstance(ul, ErrorFeedback) else ul

    def _use_vectorized(self) -> bool:
        """Whether the stacked/vmapped local phase can serve this round.

        vmap groups clients by identical static config: all per-client
        FIRMConfigs must agree once ``preference`` is lifted to a traced
        array (every client has a preference vector, or none does).
        """
        if not self.ec.vectorized_clients:
            return False
        if self.ec.algorithm not in ("firm", "firm_unreg", "fedcmoo",
                                     "linear"):
            return False
        base = dataclasses.replace(self._client_fcs[0], preference=None)
        if any(dataclasses.replace(f, preference=None) != base
               for f in self._client_fcs[1:]):
            return False
        has = [f.preference is not None for f in self._client_fcs]
        if any(has) and not all(has):
            return False
        return True

    # ------------------------------------------------------------------
    def run_round(self) -> dict:
        fc = self._fc_for_algorithm()
        participants = self._sample_participants()
        dispatch0 = self.jit_dispatches
        # broadcast θ_t through the downlink codec; every client receives
        # (and trains from) the same decoded broadcast
        dl_payload, self._downlink_state, broadcast = \
            self.downlink_codec.roundtrip(
                self.global_trainable, self._downlink_state,
                key=self._next_key())
        for c in participants:
            self.ledger.send_down(dl_payload)

        if self._use_vectorized():
            lams, rewards_mean, kl_mean, stacked_tr = \
                self._local_phase_vectorized(fc, participants, broadcast)
        else:
            lams, rewards_mean, kl_mean, stacked_tr = \
                self._local_phase_loop(fc, participants, broadcast)

        # participating clients transmit adapted-param deltas through the
        # uplink codec (residuals stay client-local); the delta against
        # the broadcast anchor flattens in one batched tree op over the
        # stacked axis, the codec runs per client at the (flat) Payload
        # boundary, and the server FedAvgs the decoded deltas in one
        # stacked mean + single unflatten
        flat_deltas = _delta_flat_jit(stacked_tr, broadcast)
        self.jit_dispatches += 1
        decoded = []
        for ci, c in enumerate(participants):
            payload, self._uplink_state[c], dec = \
                self.uplink_codec.roundtrip_flat(
                    flat_deltas[ci], self._delta_spec,
                    self._uplink_state[c], key=self._next_key())
            self.ledger.send_up(payload)
            decoded.append(dec)
        self.global_trainable = _jit_flat_aggregate(self._delta_spec)(
            broadcast, *decoded)
        self.jit_dispatches += 1
        self.ledger.next_round()

        # metrics were accumulated on device; ONE host transfer per round
        stats = _summary_device(lams, rewards_mean, kl_mean, stacked_tr)
        self.jit_dispatches += 1
        host = jax.device_get(stats)
        summary = {
            "rewards": host["rewards"],
            "lam_mean": host["lam_mean"],
            "lam_disagreement": float(host["lam_disagreement"]),
            "param_drift": float(host["param_drift"]),
            "kl": float(host["kl"]),
            "comm_bytes": self.ledger.total,
            "up_bytes": self.ledger.up_bytes,
            "down_bytes": self.ledger.down_bytes,
            "participants": participants,
            "per_client_lam": host["per_client_lam"],
            "dispatches": self.jit_dispatches - dispatch0,
        }
        self.history.append(summary)
        return summary

    # ------------------------------------------------- per-client loop path
    def _local_phase_loop(self, fc: FIRMConfig, participants: List[int],
                          broadcast):
        # the jitted local step donates its state argument, so every
        # participant must OWN its trainable buffers: adopt the broadcast
        # by copy, never by alias (the anchor must survive for the delta,
        # and clients must not share donated buffers)
        for c in participants:
            self.client_states[c] = self.client_states[c]._replace(
                trainable=jax.tree_util.tree_map(jnp.copy, broadcast))
        round_metrics = []
        if self.ec.algorithm in ("firm", "firm_unreg"):
            for k in range(fc.local_steps):
                for c in participants:
                    batch = self._make_batch(c)
                    self.client_states[c], m = self._jit_steps[c](
                        self.client_states[c], self.frozen, batch)
                    self.jit_dispatches += 1
                    m["client"] = c
                    round_metrics.append(m)
        elif self.ec.algorithm == "fedcmoo":
            grad_codec = self._grad_codec()
            for k in range(fc.local_steps):
                per_client = []
                server_grads = []
                for c in participants:
                    batch = self._make_batch(c)
                    grads, losses, extras = local_lib.fedcmoo_local_grads(
                        self.cfg, fc, self.client_states[c], self.frozen,
                        batch)
                    per_client.append((grads, extras, batch.rewards.mean(0)))
                    # gradients go up every local step: the O(CMd) cost;
                    # the server solves λ from what it actually receives
                    # (codec error feeds the q-term, Askin et al. Rmk 4.6)
                    received = []
                    for g in grads:
                        gp, _, dec = grad_codec.roundtrip(
                            g, key=self._next_key())
                        self.ledger.send_up(gp)
                        received.append(dec)
                    server_grads.append(received)
                lam = fedcmoo.fedcmoo_round_lambda(
                    server_grads,
                    compress_rank=self.ec.fedcmoo_compress_rank,
                    key=self._next_key())
                for ci, c in enumerate(participants):
                    grads, extras, rmean = per_client[ci]
                    self.client_states[c], m = local_lib.fedcmoo_local_apply(
                        fc, self.client_states[c], grads, lam, extras)
                    m["client"] = c
                    m["rewards"] = rmean
                    round_metrics.append(m)
        elif self.ec.algorithm == "linear":
            w = jnp.asarray(self.ec.linear_weights
                            or [1.0 / fc.n_objectives] * fc.n_objectives,
                            jnp.float32)
            for k in range(fc.local_steps):
                for c in participants:
                    batch = self._make_batch(c)
                    grads, losses, extras = local_lib.fedcmoo_local_grads(
                        self.cfg, fc, self.client_states[c], self.frozen,
                        batch)
                    self.client_states[c], m = local_lib.fedcmoo_local_apply(
                        fc, self.client_states[c], grads, w, extras)
                    m["client"] = c
                    m["rewards"] = batch.rewards.mean(0)
                    round_metrics.append(m)
        else:
            raise ValueError(self.ec.algorithm)

        # metrics stay device-resident: stack on device, convert to host
        # once per round in run_round's summary
        lams = jnp.stack([m["lam"] for m in round_metrics
                          if "lam" in m][-len(participants):])
        rewards_mean = jnp.stack([m["rewards"]
                                  for m in round_metrics]).mean(0)
        kl_mean = jnp.stack([m["kl"] for m in round_metrics]).mean()
        stacked_tr = _stack_trees_jit(
            *[self.client_states[c].trainable for c in participants])
        self.jit_dispatches += 1
        return lams, rewards_mean, kl_mean, stacked_tr

    # ------------------------------------------------- vectorized path
    def _local_phase_vectorized(self, fc: FIRMConfig,
                                participants: List[int], broadcast):
        p_count = len(participants)
        k_steps = fc.local_steps
        m = fc.n_objectives
        has_pref = self._stacked_pref is not None
        cfc = dataclasses.replace(fc, preference=None) if has_pref else fc

        counts0 = jnp.asarray([self.datasets[c]._count
                               for c in participants], jnp.int32)
        if p_count == self.fc.n_clients:     # full participation: cached
            seeds, probs = self._seeds_all, self._probs_all
            band_h, band_x = self._bands_h, self._bands_x
            pref = self._stacked_pref if has_pref else None
        else:
            idx = jnp.asarray(participants, jnp.int32)
            seeds, probs = self._seeds_all[idx], self._probs_all[idx]
            band_h, band_x = self._bands_h[idx], self._bands_x[idx]
            pref = self._stacked_pref[idx] if has_pref else None
        # advance the per-client prompt streams exactly as the loop would
        for c in participants:
            self.datasets[c]._count += k_steps

        # stacking copies the broadcast into a fresh (C, ...) buffer, so
        # the stacked state is safe to donate and the anchor survives
        states = [self.client_states[c]._replace(trainable=broadcast)
                  for c in participants]
        stacked = _stack_trees_jit(*states)
        self.jit_dispatches += 1

        if self.ec.algorithm == "fedcmoo":
            lams, rewards_mean, kl_mean, stacked = self._vec_fedcmoo_steps(
                cfc, participants, stacked, seeds, counts0, probs,
                band_h, band_x)
        else:
            # per-client generation keys, drawn in the loop path's order
            # (step-major, then participant order) for exact key parity
            gen_keys = jnp.stack(
                [jnp.stack([self._next_key() for _ in participants])
                 for _ in range(k_steps)])
            lin_w = None
            if self.ec.algorithm == "linear":
                lin_w = jnp.asarray(
                    self.ec.linear_weights or [1.0 / m] * m, jnp.float32)
            alg = "linear" if self.ec.algorithm == "linear" else "firm"
            fn = _jit_vec_round(self.cfg, cfc, alg, self.ec.prompt_len,
                                self.ec.max_new, self._length_tol, has_pref)
            stacked, ms = fn(stacked, self.frozen, self.ref_params, seeds,
                             counts0, probs, band_h, band_x, gen_keys,
                             pref, lin_w)
            self.jit_dispatches += 1
            lams = ms["lam"][-1]                              # (C, M)
            rewards_mean = ms["rewards"].reshape(-1, m).mean(0)
            kl_mean = ms["kl"].mean()

        new_states = _jit_unstack(p_count)(stacked)
        self.jit_dispatches += 1
        for ci, c in enumerate(participants):
            self.client_states[c] = new_states[ci]
        return lams, rewards_mean, kl_mean, stacked.trainable

    def _vec_fedcmoo_steps(self, cfc: FIRMConfig, participants: List[int],
                           stacked, seeds, counts0, probs, band_h, band_x):
        """FedCMOO vectorized local phase: two jitted dispatches per step
        (vmapped grads, vmapped apply) around the host-side server
        exchange — per-client codec Payloads + one global λ solve."""
        m = cfc.n_objectives
        grad_codec = self._grad_codec()
        grads_fn = _jit_vec_fedcmoo_grads(self.cfg, cfc, self.ec.max_new,
                                          self._length_tol)
        apply_fn = _jit_vec_fedcmoo_apply(cfc)
        sampler = _jit_sample_block(cfc.batch_size, self.ec.prompt_len,
                                    self.cfg.vocab)
        lam_last, rew_hist, kl_hist = None, [], []
        for k in range(cfc.local_steps):
            # key parity with the loop path: per client, one batch key
            # then M gradient-codec keys, interleaved in participant order
            kb, kg = [], []
            for _ in participants:
                kb.append(self._next_key())
                kg.append([self._next_key() for _ in range(m)])
            prompts = sampler(seeds, counts0 + k, probs)
            self.jit_dispatches += 1
            grads, extras, rmean = grads_fn(
                stacked, self.frozen, self.ref_params, prompts,
                jnp.stack(kb), band_h, band_x)
            self.jit_dispatches += 1
            server_grads = []
            for ci in range(len(participants)):
                received = []
                for j in range(m):
                    g_c = jax.tree_util.tree_map(lambda x: x[ci], grads[j])
                    gp, _, dec = grad_codec.roundtrip(g_c, key=kg[ci][j])
                    self.ledger.send_up(gp)
                    received.append(dec)
                server_grads.append(received)
            lam = fedcmoo.fedcmoo_round_lambda(
                server_grads,
                compress_rank=self.ec.fedcmoo_compress_rank,
                key=self._next_key())
            stacked, metrics = apply_fn(stacked, grads, lam, extras)
            self.jit_dispatches += 1
            lam_last = metrics["lam"]
            rew_hist.append(rmean)
            kl_hist.append(metrics["kl"])
        rewards_mean = jnp.stack(rew_hist).reshape(-1, m).mean(0)
        kl_mean = jnp.stack(kl_hist).mean()
        return lam_last, rewards_mean, kl_mean, stacked

    def run(self, rounds: Optional[int] = None) -> List[dict]:
        for _ in range(rounds or self.fc.rounds):
            self.run_round()
        return self.history
