"""Mamba2 (SSD — state-space duality) block.

Training/prefill uses the *chunked* SSD algorithm: within a chunk the
output is a decay-masked quadratic form (attention-like, MXU friendly);
across chunks a (B, nh, hd, dstate) state is carried through a lax.scan.
Peak memory is O(S * chunk) instead of the O(S * hd * dstate) blow-up of a
naive associative scan.  Decode is the exact single-step recurrence.

Adaptation note (DESIGN §3): the reference CUDA kernel fuses the chunk
scan; here the chunk body is plain einsum so the MXU executes the
(chunk x chunk) and (chunk x dstate) contractions, and the cross-chunk
recurrence is a sequential lax.scan of tiny state tensors.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common

def dims(cfg: ModelConfig):
    din = cfg.ssm_expand * cfg.d_model
    nheads = max(1, din // cfg.ssm_head_dim)
    return din, nheads, cfg.ssm_head_dim, cfg.ssm_state


def init_mamba2(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    d = cfg.d_model
    din, nh, hd, ds = dims(cfg)
    ks = jax.random.split(key, 4)
    conv_ch = din + 2 * ds
    return {
        "ln": common.init_norm(d, dtype),
        # in_proj -> [z(din), x(din), B(ds), C(ds), dt(nh)]
        "in_proj": common.init_linear(ks[0], d, 2 * din + 2 * ds + nh,
                                      dtype=dtype),
        "conv_w": common._normal(ks[1], (cfg.conv_dim, conv_ch),
                                 1.0 / cfg.conv_dim, dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "out_proj": common.init_linear(ks[2], din, d, dtype=dtype),
    }


def _split_proj(cfg, zxbcdt):
    din, nh, hd, ds = dims(cfg)
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din:din + din + 2 * ds]
    dt = zxbcdt[..., din + din + 2 * ds:]
    return z, xbc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv, xbc: (B, S, C); w: (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu((out + b).astype(jnp.float32))


def mamba2_seq(p, cfg: ModelConfig, x: jnp.ndarray,
               return_state: bool = False):
    """Full-sequence forward.  x: (B, S, d) -> (B, S, d) [, final cache]."""
    din, nh, hd, ds = dims(cfg)
    b, s, _ = x.shape
    h = common.rms_norm(p["ln"], x, cfg.norm_eps)
    z, xbc, dt_raw = _split_proj(cfg, common.linear(p["in_proj"], h))
    xbc_raw = xbc                                              # pre-conv (cache)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])          # (B,S,din+2ds) f32
    xs = xbc[..., :din].reshape(b, s, nh, hd)
    B = xbc[..., din:din + ds]                                  # (B,S,ds)
    C = xbc[..., din + ds:]                                     # (B,S,ds)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    A = -jnp.exp(p["A_log"])                                    # (nh,) < 0
    dA = dt * A                                                 # (B,S,nh) log-decay

    chunk = cfg.ssm_chunk
    nchunks = -(-s // chunk)
    pad = nchunks * chunk - s
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
    cs = nchunks

    def rs(t, extra):  # (B, S', ...) -> (cs, B, chunk, ...)
        return jnp.moveaxis(t.reshape((b, cs, chunk) + extra), 1, 0)

    xs_c, B_c, C_c = rs(xs, (nh, hd)), rs(B, (ds,)), rs(C, (ds,))
    dt_c, dA_c = rs(dt, (nh,)), rs(dA, (nh,))

    def body(state, xs_):
        xck, bck, cck, dtk, dak = xs_      # per-chunk tensors
        # cumulative log decay within chunk, inclusive: L (B, CHUNK, nh)
        L = jnp.cumsum(dak, axis=1)
        # intra-chunk: scores[i,j] = (C_i . B_j) * exp(L_i - L_j) * dt_j, i>=j
        cb = jnp.einsum("bis,bjs->bij", cck, bck)              # (B,Ck,Ck)
        ii = jnp.arange(chunk)
        causal = ii[:, None] >= ii[None, :]
        ldiff = L[:, :, None, :] - L[:, None, :, :]            # (B,i,j,nh)
        decay = jnp.exp(jnp.where(causal[None, :, :, None], ldiff, -jnp.inf))
        scores = cb[..., None] * decay
        scores = scores * dtk[:, None, :, :]                   # weight by dt_j
        y_intra = jnp.einsum("bijh,bjhd->bihd", scores, xck)
        # inter-chunk: y_i += (C_i . state) * exp(L_i)
        y_inter = jnp.einsum("bis,bhds->bihd", cck, state) * \
            jnp.exp(L)[:, :, :, None]
        # new state: exp(L_end - L_j) dt_j  x_j B_j^T  summed, plus decayed old
        decay_end = jnp.exp(L[:, -1:, :] - L)                  # (B,Ck,nh)
        w = (dtk * decay_end)[..., None]                       # (B,Ck,nh,1)
        state_new = jnp.einsum("bjhd,bjs->bhds", xck * w, bck)
        state = state * jnp.exp(L[:, -1])[:, :, None, None] + state_new
        return state, y_intra + y_inter

    state0 = jnp.zeros((b, nh, hd, ds), jnp.float32)
    state_f, y = jax.lax.scan(body, state0, (xs_c, B_c, C_c, dt_c, dA_c))
    y = jnp.moveaxis(y, 0, 1).reshape(b, cs * chunk, nh, hd)[:, :s]
    y = y + xs[:, :s] * p["D"][None, None, :, None]
    y = (y * jax.nn.silu(z.astype(jnp.float32)).reshape(b, s, nh, hd)
         ).reshape(b, s, din)
    out = common.linear(p["out_proj"], y.astype(x.dtype))
    if return_state:
        # conv cache: last (conv_dim-1) raw (pre-conv, pre-silu) channels
        kconv = cfg.conv_dim - 1
        hist = xbc_raw[:, -kconv:].astype(jnp.float32)
        if s < kconv:
            hist = jnp.pad(hist, ((0, 0), (kconv - s, 0), (0, 0)))
        return x + out, {"conv": hist, "state": state_f}
    return x + out


def init_mamba2_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    din, nh, hd, ds = dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.conv_dim - 1, din + 2 * ds), dtype),
        "state": jnp.zeros((batch, nh, hd, ds), dtype),
    }


def mamba2_decode(p, cfg: ModelConfig, x: jnp.ndarray, cache):
    """One step.  x: (B, 1, d) -> (y: (B, 1, d), cache)."""
    din, nh, hd, ds = dims(cfg)
    b = x.shape[0]
    h = common.rms_norm(p["ln"], x, cfg.norm_eps)
    z, xbc, dt_raw = _split_proj(cfg, common.linear(p["in_proj"], h))
    xbc = xbc[:, 0]                                             # (B, C)
    hist = jnp.concatenate([cache["conv"],
                            xbc[:, None].astype(cache["conv"].dtype)], axis=1)
    w = p["conv_w"]
    conv = (hist * w[None]).sum(axis=1) + p["conv_b"]
    xbc = jax.nn.silu(conv.astype(jnp.float32))
    xst = xbc[:, :din].reshape(b, nh, hd)
    B = xbc[:, din:din + ds]
    C = xbc[:, din + ds:]
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                                        # (B, nh)
    state = cache["state"] * dA[:, :, None, None] + \
        jnp.einsum("bhd,bs->bhds", xst * dt[..., None], B)
    y = jnp.einsum("bhds,bs->bhd", state, C) + xst * p["D"][None, :, None]
    y = y * jax.nn.silu(z[:, 0].astype(jnp.float32)).reshape(b, nh, hd)
    out = common.linear(p["out_proj"], y.reshape(b, 1 * din)[:, None, :]
                        .astype(x.dtype))
    new_cache = {"conv": hist[:, 1:], "state": state}
    return x + out, new_cache
