from repro.models import attention, common, moe, ssm, transformer, xlstm  # noqa
from repro.models.transformer import (decode_step, forward_seq, init_cache,
                                      init_params, prefill)

__all__ = ["attention", "common", "moe", "ssm", "transformer", "xlstm",
           "init_params", "forward_seq", "prefill", "decode_step",
           "init_cache"]
