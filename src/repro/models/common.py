"""Shared model primitives: RMSNorm, RoPE, SwiGLU, LoRA-aware projections.

Parameters are plain nested dicts of jnp arrays ("param trees").  A linear
projection is a dict ``{'w': (din, dout)}`` optionally carrying LoRA factors
``{'lora_A': (din, r), 'lora_B': (r, dout)}``.  LoRA factors are the only
trainable leaves in federated mode (paper trains/communicates adapters only).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


Param = dict  # nested dict pytree of jnp arrays


# --------------------------------------------------------------------- init
def _normal(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_linear(key, din: int, dout: int, *, lora_rank: int = 0,
                dtype=jnp.bfloat16, scale: Optional[float] = None) -> Param:
    scale = scale if scale is not None else 1.0 / math.sqrt(din)
    p = {"w": _normal(key, (din, dout), scale, dtype)}
    if lora_rank:
        ka, _ = jax.random.split(key)
        # A ~ N(0, 1/r), B = 0 (standard LoRA init: adapter starts at zero)
        p["lora_A"] = _normal(ka, (din, lora_rank), 1.0 / math.sqrt(din),
                              jnp.float32)
        p["lora_B"] = jnp.zeros((lora_rank, dout), jnp.float32)
    return p


def init_norm(d: int, dtype=jnp.bfloat16) -> Param:
    return {"g": jnp.ones((d,), dtype)}


# ------------------------------------------------------------------ forward
def linear(p: Param, x: jnp.ndarray, *, lora_alpha: float = 32.0) -> jnp.ndarray:
    """x @ w (+ LoRA path).  x: (..., din) -> (..., dout)."""
    y = x @ p["w"]
    if "lora_A" in p:
        r = p["lora_A"].shape[-1]
        z = (x.astype(jnp.float32) @ p["lora_A"]) @ p["lora_B"]
        y = y + (lora_alpha / r) * z.astype(y.dtype)
    return y


def rms_norm(p: Param, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * p["g"]


def swiglu(p: Param, x: jnp.ndarray) -> jnp.ndarray:
    """SwiGLU MLP: p has 'w_gate', 'w_up', 'w_down'."""
    g = linear(p["w_gate"], x)
    u = linear(p["w_up"], x)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return linear(p["w_down"], h)


def init_swiglu(key, d: int, dff: int, dtype=jnp.bfloat16) -> Param:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": init_linear(k1, d, dff, dtype=dtype),
        "w_up": init_linear(k2, d, dff, dtype=dtype),
        "w_down": init_linear(k3, dff, d, dtype=dtype,
                              scale=1.0 / math.sqrt(dff)),
    }


# ---------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (B, S, H, Dh); positions: (S,) or (B, S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    if ang.ndim == 2:                                   # (S, Dh/2) -> broadcast
        ang = ang[None]                                 # (1, S, Dh/2)
    cos = jnp.cos(ang)[:, :, None, :]                   # (B|1, S, 1, Dh/2)
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., 0::2].astype(jnp.float32), x[..., 1::2].astype(jnp.float32)
    o1 = x1 * cos - x2 * sin
    o2 = x1 * sin + x2 * cos
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ------------------------------------------------------------------- pytrees
def tree_size(tree) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


def is_lora_path(path) -> bool:
    return any(getattr(k, "key", None) in ("lora_A", "lora_B") for k in path)


def split_trainable(params, full_params_mode: bool = False):
    """Split params into (trainable, frozen) trees with None placeholders.

    In LoRA mode trainable = the lora_A/lora_B leaves (paper: adapters only).
    In full mode everything is trainable.
    """
    if full_params_mode:
        return params, jax.tree_util.tree_map(lambda _: None, params)
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    has_lora = any(is_lora_path(p) for p, _ in flat)
    if not has_lora:            # e.g. xlstm: no adapters -> full-param FIRM
        return params, jax.tree_util.tree_map(lambda _: None, params)
    train = jax.tree_util.tree_map_with_path(
        lambda p, x: x if is_lora_path(p) else None, params)
    frozen = jax.tree_util.tree_map_with_path(
        lambda p, x: None if is_lora_path(p) else x, params)
    return train, frozen


def merge_trainable(train, frozen):
    return jax.tree_util.tree_map(
        lambda a, b: a if a is not None else b, train, frozen,
        is_leaf=lambda x: x is None)
