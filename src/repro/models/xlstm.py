"""xLSTM blocks: mLSTM (matrix memory / linear attention) and sLSTM.

mLSTM keeps a per-head matrix state C (Dh x Dh) with exponential
input/forget gating and a max-stabiliser m (arXiv:2405.04517 Eq. 19-27).
Training runs the exact recurrence as a lax.scan over time (state tensors
are small at this scale); decode is the single-step recurrence.

sLSTM is the scalar-memory cell with recurrent (hidden-to-gate) weights —
inherently sequential, also a lax.scan over time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common


# ------------------------------------------------------------------- mLSTM
def init_mlstm(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    d = cfg.d_model
    dq = cfg.n_heads * cfg.head_dim
    ks = jax.random.split(key, 6)
    return {
        "ln": common.init_norm(d, dtype),
        "wq": common.init_linear(ks[0], d, dq, dtype=dtype),
        "wk": common.init_linear(ks[1], d, dq, dtype=dtype),
        "wv": common.init_linear(ks[2], d, dq, dtype=dtype),
        "w_if": common.init_linear(ks[3], d, 2 * cfg.n_heads,
                                   dtype=jnp.float32),
        "w_o": common.init_linear(ks[4], d, dq, dtype=dtype),   # output gate
        "out_proj": common.init_linear(ks[5], dq, d, dtype=dtype),
    }


def _mlstm_step(state, q, k, v, i_log, f_log):
    """One mLSTM cell step.  q,k,v: (B,H,Dh); gates: (B,H)."""
    C, n, m = state
    m_new = jnp.maximum(f_log + m, i_log)                       # (B,H)
    f_act = jnp.exp(f_log + m - m_new)[..., None]
    i_act = jnp.exp(i_log - m_new)[..., None]
    C = C * f_act[..., None] + i_act[..., None] * \
        (k[..., :, None] * v[..., None, :])                     # (B,H,Dh,Dh)
    n = n * f_act + i_act * k
    h_num = jnp.einsum("bhij,bhi->bhj", C, q)
    h_den = jnp.maximum(jnp.abs(jnp.einsum("bhi,bhi->bh", n, q)), 1.0)
    h = h_num / h_den[..., None]
    return (C, n, m_new), h


def _mlstm_qkvg(p, cfg, x):
    b, s, _ = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    hin = common.rms_norm(p["ln"], x, cfg.norm_eps)
    q = common.linear(p["wq"], hin).reshape(b, s, h, dh).astype(jnp.float32)
    k = common.linear(p["wk"], hin).reshape(b, s, h, dh).astype(jnp.float32)
    k = k / jnp.sqrt(float(dh))
    v = common.linear(p["wv"], hin).reshape(b, s, h, dh).astype(jnp.float32)
    gates = common.linear(p["w_if"], hin).astype(jnp.float32)   # (B,S,2H)
    i_log = gates[..., :h]
    f_log = jax.nn.log_sigmoid(gates[..., h:] + 3.0)
    o = jax.nn.sigmoid(common.linear(p["w_o"], hin).astype(jnp.float32))
    return q, k, v, i_log, f_log, o


def mlstm_seq(p, cfg: ModelConfig, x: jnp.ndarray,
              return_state: bool = False):
    if cfg.mlstm_chunk:
        return mlstm_seq_chunked(p, cfg, x, return_state=return_state,
                                 chunk=cfg.mlstm_chunk)
    return mlstm_seq_recurrent(p, cfg, x, return_state=return_state)


def mlstm_seq_recurrent(p, cfg: ModelConfig, x: jnp.ndarray,
                        return_state: bool = False):
    """Exact per-token recurrence (reference path; O(S) HBM round-trips
    of the matrix state — see EXPERIMENTS §Perf hillclimb #1)."""
    b, s, d = x.shape
    hh, dh = cfg.n_heads, cfg.head_dim
    q, k, v, i_log, f_log, o = _mlstm_qkvg(p, cfg, x)

    def body(state, xs):
        qt, kt, vt, it, ft = xs
        state, h = _mlstm_step(state, qt, kt, vt, it, ft)
        return state, h

    state0 = (jnp.zeros((b, hh, dh, dh), jnp.float32),
              jnp.zeros((b, hh, dh), jnp.float32),
              jnp.zeros((b, hh), jnp.float32))
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (q, k, v, i_log, f_log))
    state_f, hs = jax.lax.scan(body, state0, xs)                # (S,B,H,Dh)
    hs = jnp.moveaxis(hs, 0, 1).reshape(b, s, hh * dh)
    y = hs * o.reshape(b, s, hh * dh)
    out = x + common.linear(p["out_proj"], y.astype(x.dtype))
    if return_state:
        return out, {"C": state_f[0], "n": state_f[1], "m": state_f[2]}
    return out


def mlstm_seq_chunked(p, cfg: ModelConfig, x: jnp.ndarray,
                      return_state: bool = False, chunk: int = 64):
    """Chunkwise-parallel mLSTM (stabilised linear attention).

    Within a chunk the output is a decay-masked (q·k) quadratic form on
    the MXU; across chunks only the (B, H, Dh, Dh) matrix state is carried
    through a lax.scan — HBM traffic drops from O(S) state round-trips to
    O(S/chunk) (EXPERIMENTS §Perf hillclimb #1).  Exactly equals the
    recurrent path (same max-stabilised exponential gating).
    """
    b, s, d = x.shape
    hh, dh = cfg.n_heads, cfg.head_dim
    q, k, v, i_log, f_log, o = _mlstm_qkvg(p, cfg, x)

    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        # padded steps: f_log = 0 is WRONG (adds decay); use f=0 -> log 1?
        # f_log pad 0.0 keeps state scale; i_log pad -inf kills input.
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        i_log = jnp.pad(i_log, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-1e30)
        f_log = jnp.pad(f_log, ((0, 0), (0, pad), (0, 0)))

    def rs(t, extra):
        return jnp.moveaxis(t.reshape((b, nc, chunk) + extra), 1, 0)

    qc, kc, vc = rs(q, (hh, dh)), rs(k, (hh, dh)), rs(v, (hh, dh))
    ic, fc = rs(i_log, (hh,)), rs(f_log, (hh,))

    ii = jnp.arange(chunk)
    causal = ii[:, None] >= ii[None, :]

    def body(carry, xs):
        C, n, m = carry                     # (B,H,Dh,Dh),(B,H,Dh),(B,H)
        qk, kk, vk, ik, fk = xs
        F = jnp.cumsum(fk, axis=1)          # (B,chunk,H) inclusive
        # log-weights: intra a[i,j] = F_i - F_j + i_j (j<=i); inter = F_i + m
        a_intra = F[:, :, None, :] - F[:, None, :, :] + ik[:, None, :, :]
        a_intra = jnp.where(causal[None, :, :, None], a_intra, -jnp.inf)
        m_intra = a_intra.max(axis=2)       # (B,chunk,H)
        m_inter = F + m[:, None, :]
        m_comb = jnp.maximum(jnp.maximum(m_intra, m_inter), -1e30)
        # intra-chunk numerator / denominator
        w = jnp.exp(a_intra - m_comb[:, :, None, :])    # (B,i,j,H)
        qkd = jnp.einsum("bihe,bjhe->bijh", qk, kk)     # (B,i,j,H)
        h_num = jnp.einsum("bijh,bjhe->bihe", w * qkd, vk)
        n_dot = jnp.einsum("bijh,bjhe,bihe->bih", w, kk, qk)
        # inter-chunk
        scale_i = jnp.exp(m_inter - m_comb)             # (B,chunk,H)
        h_num = h_num + jnp.einsum("bihe,bhed->bihd", qk, C) * \
            scale_i[..., None]
        n_dot = n_dot + jnp.einsum("bihe,bhe->bih", qk, n) * scale_i
        # same floor as the recurrent cell (_mlstm_step): max(|n.q|, 1)
        denom = jnp.maximum(jnp.abs(n_dot), 1.0)
        h = h_num / denom[..., None]                     # (B,chunk,H,Dh)
        # state update to end of chunk
        F_last = F[:, -1:, :]                            # (B,1,H)
        g = F_last - F + ik                              # (B,chunk,H)
        m_state = jnp.maximum(F_last[:, 0] + m, g.max(axis=1))   # (B,H)
        wS = jnp.exp(g - m_state[:, None, :])            # (B,chunk,H)
        C_new = C * jnp.exp(F_last[:, 0] + m - m_state)[..., None, None] + \
            jnp.einsum("bjh,bjhe,bjhd->bhed", wS, kk, vk)
        n_new = n * jnp.exp(F_last[:, 0] + m - m_state)[..., None] + \
            jnp.einsum("bjh,bjhe->bhe", wS, kk)
        return (C_new, n_new, m_state), h

    state0 = (jnp.zeros((b, hh, dh, dh), jnp.float32),
              jnp.zeros((b, hh, dh), jnp.float32),
              jnp.zeros((b, hh), jnp.float32))
    state_f, hs = jax.lax.scan(body, state0, (qc, kc, vc, ic, fc))
    hs = jnp.moveaxis(hs, 0, 1).reshape(b, nc * chunk, hh * dh)[:, :s]
    y = hs * o.reshape(b, s, hh * dh)
    out = x + common.linear(p["out_proj"], y.astype(x.dtype))
    if return_state:
        return out, {"C": state_f[0], "n": state_f[1], "m": state_f[2]}
    return out


def init_mlstm_cache(cfg: ModelConfig, batch: int):
    hh, dh = cfg.n_heads, cfg.head_dim
    return {"C": jnp.zeros((batch, hh, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, hh, dh), jnp.float32),
            "m": jnp.zeros((batch, hh), jnp.float32)}


def mlstm_decode(p, cfg: ModelConfig, x: jnp.ndarray, cache):
    b = x.shape[0]
    hh, dh = cfg.n_heads, cfg.head_dim
    q, k, v, i_log, f_log, o = _mlstm_qkvg(p, cfg, x)
    state = (cache["C"], cache["n"], cache["m"])
    state, h = _mlstm_step(state, q[:, 0], k[:, 0], v[:, 0],
                           i_log[:, 0], f_log[:, 0])
    y = (h.reshape(b, 1, hh * dh) * o)
    out = x + common.linear(p["out_proj"], y.astype(x.dtype))
    return out, {"C": state[0], "n": state[1], "m": state[2]}


# ------------------------------------------------------------------- sLSTM
def init_slstm(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    """Block-diagonal sLSTM (xLSTM §2.2: per-head recurrence).

    The recurrent matrix acts within heads only — this is both the
    paper's design and what keeps the sequential time scan free of
    cross-device collectives when heads are sharded (EXPERIMENTS §Perf
    hillclimb #1, iteration 3).
    """
    d = cfg.d_model
    hh = cfg.n_heads
    dh = d // hh
    ks = jax.random.split(key, 3)
    return {
        "ln": common.init_norm(d, dtype),
        "w": common.init_linear(ks[0], d, 4 * d, dtype=jnp.float32),
        "r": common._normal(ks[1], (hh, dh, 4 * dh), 1.0 / jnp.sqrt(dh),
                            jnp.float32),
        "b": jnp.zeros((4 * d,), jnp.float32),
        "out_proj": common.init_linear(ks[2], d, d, dtype=dtype),
    }


def _recur(p, d, h):
    """Block-diagonal recurrent projection: (B, d) -> (B, 4d)."""
    hh, dh, _ = p["r"].shape
    b = h.shape[0]
    pre = jnp.einsum("bhe,hef->bhf", h.reshape(b, hh, dh), p["r"])
    # head-major gate layout: regroup to (i|f|z|o) x d
    pre = pre.reshape(b, hh, 4, dh)
    return jnp.moveaxis(pre, 2, 1).reshape(b, 4 * d)


def _slstm_step(p, d, state, wx_t):
    c, n, h, m = state                                           # (B,d) each
    pre = wx_t + _recur(p, d, h) + p["b"]                        # (B,4d)
    i_log, f_pre, z_pre, o_pre = jnp.split(pre, 4, axis=-1)
    f_log = jax.nn.log_sigmoid(f_pre + 3.0)
    m_new = jnp.maximum(f_log + m, i_log)
    i_act = jnp.exp(i_log - m_new)
    f_act = jnp.exp(f_log + m - m_new)
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    c = f_act * c + i_act * z
    n = f_act * n + i_act
    h = o * c / jnp.maximum(n, 1.0)
    return (c, n, h, m_new), h


def slstm_seq(p, cfg: ModelConfig, x: jnp.ndarray,
              return_state: bool = False):
    b, s, d = x.shape
    hin = common.rms_norm(p["ln"], x, cfg.norm_eps).astype(jnp.float32)
    wx = common.linear(p["w"], hin)                              # (B,S,4d)

    def body(state, wx_t):
        return _slstm_step(p, d, state, wx_t)

    z0 = jnp.zeros((b, d), jnp.float32)
    state0 = (z0, z0, z0, z0)
    state_f, hs = jax.lax.scan(body, state0, jnp.moveaxis(wx, 1, 0))
    hs = jnp.moveaxis(hs, 0, 1)                                  # (B,S,d)
    out = x + common.linear(p["out_proj"], hs.astype(x.dtype))
    if return_state:
        return out, {"c": state_f[0], "n": state_f[1], "h": state_f[2],
                     "m": state_f[3]}
    return out


def init_slstm_cache(cfg: ModelConfig, batch: int):
    z = jnp.zeros((batch, cfg.d_model), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": z}


def slstm_decode(p, cfg: ModelConfig, x: jnp.ndarray, cache):
    d = cfg.d_model
    hin = common.rms_norm(p["ln"], x, cfg.norm_eps).astype(jnp.float32)
    wx = common.linear(p["w"], hin)[:, 0]                        # (B,4d)
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    state, h = _slstm_step(p, d, state, wx)
    out = x + common.linear(p["out_proj"], h[:, None].astype(x.dtype))
    return out, {"c": state[0], "n": state[1], "h": state[2], "m": state[3]}
