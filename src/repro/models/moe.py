"""Mixture-of-Experts FFN — GShard einsum dispatch (capacity + dropping).

Routing builds a (S*k, E, cap) one-hot dispatch tensor per batch row and
moves tokens with einsums only:

  buf  = einsum('bsec,bsd->becd', dispatch, x)      # tokens -> expert rows
  y    = einsum('bsec,becd->bsd', combine,  out)    # expert rows -> tokens

Why einsums: every op in both directions is a dot, so GSPMD partitions
forward AND backward cleanly (batch on 'data', expert/d_ff on 'model').
The earlier sort+scatter formulation was measured at 40 TB/device/step of
involuntary all-reduce on mixtral-8x22b train_4k — GSPMD cannot keep the
batch dim sharded through batched scatters (EXPERIMENTS §Perf hillclimb
#2).  Dispatch-einsum overhead is ~8% of expert-FFN FLOPs at E=8, k=2.

Tokens beyond an expert's capacity (cap = S*k/E * capacity_factor) are
dropped, GShard-style.  Returns (y, router load-balance aux loss).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common


def init_moe(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    d, dff, e = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    ks = jax.random.split(key, 4)
    scale = 1.0 / jnp.sqrt(d)
    return {
        "router": {"w": common._normal(ks[0], (d, e), scale, jnp.float32)},
        "experts": {
            "w_gate": common._normal(ks[1], (e, d, dff), scale, dtype),
            "w_up": common._normal(ks[2], (e, d, dff), scale, dtype),
            "w_down": common._normal(ks[3], (e, dff, d),
                                     1.0 / jnp.sqrt(dff), dtype),
        },
    }


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def moe_ffn(p, cfg: ModelConfig, x: jnp.ndarray):
    """x: (B, S, d) -> (y: (B, S, d), aux_loss: scalar f32)."""
    moe = cfg.moe
    e, k = moe.n_experts, moe.top_k
    b, s, d = x.shape

    logits = (x.astype(jnp.float32) @ p["router"]["w"])           # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_ids = jax.lax.top_k(probs, k)                    # (B, S, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balance aux (Switch): E * sum_e mean(route frac) * mean(prob)
    onehot = jax.nn.one_hot(expert_ids, e, dtype=jnp.float32)     # (B,S,k,E)
    frac = onehot.sum(axis=(0, 1, 2)) / (b * s * k)
    aux = moe.router_aux_weight * e * jnp.sum(
        frac * probs.mean(axis=(0, 1)))

    cap = _round_up(max(k, int(s * k / e * moe.capacity_factor)), 8)

    # position of each (token, choice) within its expert, priority (s, k).
    # The big (T, E, cap) one-hots are kept in the activation dtype — at
    # bf16 model scale this halves the dominant HBM traffic (§Perf #2 it3);
    # dispatch entries are exactly 0/1 and gates carry ~8 mantissa bits,
    # well inside PPO's noise floor.
    mask = onehot.reshape(b, s * k, e)                            # (B,T,E)
    pos = jnp.cumsum(mask, axis=1) - mask                         # (B,T,E)
    within = mask * (pos < cap)                                   # keep/drop
    pos_oh = jax.nn.one_hot(pos, cap, dtype=x.dtype)              # (B,T,E,cap)
    dispatch = (within[..., None].astype(x.dtype) * pos_oh)       # (B,T,E,cap)
    gate_flat = gate.reshape(b, s * k).astype(x.dtype)
    combine = dispatch * gate_flat[:, :, None, None]              # weighted

    # fold the k choices back onto tokens: (B, T=S*k, ...) -> (B,S,k,...)
    disp_tok = dispatch.reshape(b, s, k, e, cap).sum(2)           # (B,S,E,cap)
    comb_tok = combine.reshape(b, s, k, e, cap).sum(2)

    buf = jnp.einsum("bsec,bsd->becd", disp_tok, x)

    w = p["experts"]
    g = jnp.einsum("becd,edf->becf", buf, w["w_gate"])
    u = jnp.einsum("becd,edf->becf", buf, w["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(buf.dtype) * u
    out = jnp.einsum("becf,efd->becd", h, w["w_down"])            # (B,E,cap,d)

    y = jnp.einsum("bsec,becd->bsd", comb_tok.astype(out.dtype), out)
    return y.astype(x.dtype), aux
