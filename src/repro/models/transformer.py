"""Model assembly: periodic layer patterns -> scan-over-periods stacks.

Parameters live in a nested dict:

  params['embed']            (V, d) token embedding
  params['slots'][str(i)]    pattern-slot i block params, stacked over
                             n_periods on the leading axis
  params['shared']           single param set for 'shared_attn' slots
  params['encoder']          whisper encoder {'slots': {...}, 'final_norm'}
  params['final_norm'], params['lm_head']

Three entry points:
  forward_seq(cfg, params, tokens, aux)            train / teacher-forced
  prefill(cfg, params, tokens, aux, cache_len)     build decode cache
  decode_step(cfg, params, cache, token)           one token w/ cache

``aux`` carries the modality stubs: {'vision': (B, Nv, d)} for VLMs,
{'frames': (B, Te, d)} for audio enc-dec (DESIGN §4 carve-out).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common, moe as moe_lib, ssm, xlstm
from repro.models.attention import chunked_attention, decode_attention

ATTN_KINDS = ("attn", "swa", "moe", "moe_swa", "enc_attn", "shared_attn",
              "cross")


# ================================================================== init
def _init_attn(key, cfg: ModelConfig, dtype, lora: bool):
    rank = cfg.lora.rank if (lora and cfg.lora) else 0
    dq = cfg.n_heads * cfg.head_dim
    dkv = cfg.n_kv_heads * cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": common.init_linear(ks[0], cfg.d_model, dq, lora_rank=rank,
                                 dtype=dtype),
        "wk": common.init_linear(ks[1], cfg.d_model, dkv, lora_rank=rank,
                                 dtype=dtype),
        "wv": common.init_linear(ks[2], cfg.d_model, dkv, lora_rank=rank,
                                 dtype=dtype),
        "wo": common.init_linear(ks[3], dq, cfg.d_model, lora_rank=rank,
                                 dtype=dtype),
    }


def init_block(key, kind: str, cfg: ModelConfig, dtype=jnp.bfloat16):
    d = cfg.d_model
    if kind == "mamba2":
        return ssm.init_mamba2(key, cfg, dtype)
    if kind == "mlstm":
        return xlstm.init_mlstm(key, cfg, dtype)
    if kind == "slstm":
        return xlstm.init_slstm(key, cfg, dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"ln1": common.init_norm(d, dtype),
         "attn": _init_attn(k1, cfg, dtype, lora=True),
         "ln2": common.init_norm(d, dtype)}
    if kind in ("moe", "moe_swa"):
        p["moe"] = moe_lib.init_moe(k2, cfg, dtype)
    else:
        p["mlp"] = common.init_swiglu(k2, d, cfg.d_ff, dtype)
    if kind == "cross":
        p["lnx"] = common.init_norm(d, dtype)
        p["cross"] = _init_attn(k3, cfg, dtype, lora=True)
    return p


def init_params(cfg: ModelConfig, key, dtype=jnp.bfloat16):
    keys = jax.random.split(key, 6)
    params = {
        "embed": common._normal(keys[0], (cfg.vocab, cfg.d_model),
                                0.02, dtype),
        "final_norm": common.init_norm(cfg.d_model, dtype),
        "lm_head": common.init_linear(keys[1], cfg.d_model, cfg.vocab,
                                      dtype=dtype),
        "slots": {},
    }
    slot_keys = jax.random.split(keys[2], len(cfg.pattern))
    for i, kind in enumerate(cfg.pattern):
        if kind == "shared_attn":
            continue
        per_keys = jax.random.split(slot_keys[i], cfg.n_periods)
        params["slots"][str(i)] = jax.vmap(
            lambda k: init_block(k, kind, cfg, dtype))(per_keys)
    if "shared_attn" in cfg.pattern:
        params["shared"] = init_block(keys[3], "shared_attn", cfg, dtype)
    if cfg.encoder_layers:
        enc_keys = jax.random.split(keys[4], cfg.encoder_layers)
        params["encoder"] = {
            "slots": {"0": jax.vmap(
                lambda k: init_block(k, "enc_attn", cfg, dtype))(enc_keys)},
            "final_norm": common.init_norm(cfg.d_model, dtype),
        }
    return params


# ================================================================ seq mode
def _self_attention(p, cfg: ModelConfig, h, positions, kind):
    b, s, _ = h.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = common.linear(p["wq"], h).reshape(b, s, hq, dh)
    k = common.linear(p["wk"], h).reshape(b, s, hkv, dh)
    v = common.linear(p["wv"], h).reshape(b, s, hkv, dh)
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)
    sw = cfg.sliding_window if kind in ("swa", "moe_swa") else 0
    o = chunked_attention(q, k, v, causal=(kind != "enc_attn"),
                          sliding_window=sw, block=cfg.attn_block,
                          q_positions=positions, kv_positions=positions)
    return common.linear(p["wo"], o.reshape(b, s, hq * dh)), (k, v)


def _cross_attention(p, cfg: ModelConfig, h, cross_states):
    b, s, _ = h.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    n = cross_states.shape[1]
    q = common.linear(p["wq"], h).reshape(b, s, hq, dh)
    k = common.linear(p["wk"], cross_states).reshape(b, n, hkv, dh)
    v = common.linear(p["wv"], cross_states).reshape(b, n, hkv, dh)
    o = chunked_attention(q, k, v, causal=False)
    return common.linear(p["wo"], o.reshape(b, s, hq * dh)), (k, v)


def block_seq(kind: str, p, cfg: ModelConfig, x, positions, cross_states,
              collect_kv: bool):
    """Apply one block in sequence mode.  Returns (x, aux_loss, kv_piece)."""
    aux = jnp.zeros((), jnp.float32)
    stateful = {"mamba2": ssm.mamba2_seq, "mlstm": xlstm.mlstm_seq,
                "slstm": xlstm.slstm_seq}
    if kind in stateful:
        if collect_kv:
            x2, st = stateful[kind](p, cfg, x, return_state=True)
            return x2, aux, st
        return stateful[kind](p, cfg, x), aux, None
    h = common.rms_norm(p["ln1"], x, cfg.norm_eps)
    attn_out, kv = _self_attention(p["attn"], cfg, h, positions, kind)
    x = x + attn_out
    ckv = None
    if kind == "cross":
        hx = common.rms_norm(p["lnx"], x, cfg.norm_eps)
        cross_out, ckv = _cross_attention(p["cross"], cfg, hx, cross_states)
        x = x + cross_out
    h2 = common.rms_norm(p["ln2"], x, cfg.norm_eps)
    if kind in ("moe", "moe_swa"):
        y, aux = moe_lib.moe_ffn(p["moe"], cfg, h2)
    else:
        y = common.swiglu(p["mlp"], h2)
    x = x + y
    piece = None
    if collect_kv:
        piece = {"k": kv[0], "v": kv[1]}
        if ckv is not None:
            piece["ck"], piece["cv"] = ckv
    return x, aux, piece


def _encoder_forward(cfg: ModelConfig, params, frames):
    enc = params["encoder"]
    frames = frames.astype(params["embed"].dtype)
    positions = jnp.arange(frames.shape[1])
    stacked = enc["slots"]["0"]

    def body(x, p):
        x, _, _ = block_seq("enc_attn", p, cfg, x, positions, None, False)
        return x, None

    x, _ = jax.lax.scan(body, frames, stacked)
    return common.rms_norm(enc["final_norm"], x, cfg.norm_eps)


def _cross_source(cfg: ModelConfig, params, aux):
    if cfg.family == "vlm":
        return aux["vision"].astype(params["embed"].dtype)
    if cfg.is_encoder_decoder:
        return _encoder_forward(cfg, params, aux["frames"])
    return None


def forward_seq(cfg: ModelConfig, params, tokens, aux=None,
                collect_kv: bool = False, last_logit_only: bool = False):
    """tokens: (B, S) int32 -> dict(logits, hidden, aux_loss [, kv]).

    last_logit_only: compute logits for the final position only (prefill
    path — avoids materialising (B, S, V) at 32k x 200k scale).
    """
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.arange(tokens.shape[1])
    cross_states = _cross_source(cfg, params, aux or {})
    shared = params.get("shared")

    def period_body(carry, slot_params):
        x, aux_sum = carry
        pieces = {}
        for i, kind in enumerate(cfg.pattern):
            p = shared if kind == "shared_attn" else slot_params[str(i)]
            x, a, piece = block_seq(kind, p, cfg, x, positions, cross_states,
                                    collect_kv)
            aux_sum = aux_sum + a
            if collect_kv:
                pieces[str(i)] = piece
        return (x, aux_sum), pieces if collect_kv else None

    xs = {i: v for i, v in params["slots"].items()}
    body = period_body
    if cfg.remat and not collect_kv:
        # activation checkpointing: store only the period-boundary x;
        # recompute block internals in the backward pass (drops train
        # temp memory from O(L * per-layer activations) to O(L * x)).
        # remat_policy='dots' additionally saves MXU outputs (less
        # recompute traffic, more residency — §Perf hillclimb #3).
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots" else None)
        body = jax.checkpoint(period_body, policy=policy)
    (x, aux_loss), kv = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), xs)
    x = common.rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = common.linear(params["lm_head"],
                           x[:, -1:] if last_logit_only else x)
    out = {"logits": logits, "hidden": x, "aux_loss": aux_loss}
    if collect_kv:
        out["kv"] = kv
        out["cross_states"] = cross_states
    return out


# ============================================================== decode mode
def _attn_cache_len(cfg: ModelConfig, kind: str, cache_len: int) -> int:
    if kind in ("swa", "moe_swa") and cfg.sliding_window:
        return min(cfg.sliding_window, cache_len)
    return cache_len


def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               dtype=jnp.bfloat16, n_cross: int = 0):
    """Pre-allocated decode cache (one entry per pattern slot)."""
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    slots = {}
    for i, kind in enumerate(cfg.pattern):
        if kind == "mamba2":
            piece = ssm.init_mamba2_cache(cfg, batch)
        elif kind == "mlstm":
            piece = xlstm.init_mlstm_cache(cfg, batch)
        elif kind == "slstm":
            piece = xlstm.init_slstm_cache(cfg, batch)
        else:
            c = _attn_cache_len(cfg, kind, cache_len)
            piece = {"k": jnp.zeros((batch, c, hkv, dh), dtype),
                     "v": jnp.zeros((batch, c, hkv, dh), dtype)}
            if kind == "cross":
                nc = n_cross or cfg.n_vision_tokens or 1
                piece["ck"] = jnp.zeros((batch, nc, hkv, dh), dtype)
                piece["cv"] = jnp.zeros((batch, nc, hkv, dh), dtype)
        # stack over periods
        slots[str(i)] = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_periods,) + a.shape),
            piece)
    return {"slots": slots, "pos": jnp.zeros((), jnp.int32)}


def _ring_positions(pos, c, full_len_reached_len):
    """Absolute position held by each ring slot AFTER writing token `pos`."""
    j = jnp.arange(c)
    p = pos - ((pos - j) % c)
    return jnp.where(p >= 0, p, -1)


def block_decode(kind: str, p, cfg: ModelConfig, x, cache, pos):
    """One-token decode through one block.  Returns (x, new_cache)."""
    if kind == "mamba2":
        return ssm.mamba2_decode(p, cfg, x, cache)
    if kind == "mlstm":
        return xlstm.mlstm_decode(p, cfg, x, cache)
    if kind == "slstm":
        return xlstm.slstm_decode(p, cfg, x, cache)
    b = x.shape[0]
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = common.rms_norm(p["ln1"], x, cfg.norm_eps)
    q = common.linear(p["attn"]["wq"], h).reshape(b, 1, hq, dh)
    k = common.linear(p["attn"]["wk"], h).reshape(b, 1, hkv, dh)
    v = common.linear(p["attn"]["wv"], h).reshape(b, 1, hkv, dh)
    posv = pos[None] if pos.ndim == 0 else pos
    q = common.apply_rope(q, posv, cfg.rope_theta)
    k = common.apply_rope(k, posv, cfg.rope_theta)
    c = cache["k"].shape[1]
    idx = pos % c
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                           (0, idx, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                           (0, idx, 0, 0))
    sw = cfg.sliding_window if kind in ("swa", "moe_swa") else 0
    if sw and c < cfg.sliding_window + 1:
        cache_positions = _ring_positions(pos, c, c)
    else:
        cache_positions = jnp.arange(c)
    o = decode_attention(q, k_cache, v_cache, pos, sliding_window=sw,
                         cache_positions=cache_positions)
    x = x + common.linear(p["attn"]["wo"], o.reshape(b, 1, hq * dh))
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = k_cache, v_cache
    if kind == "cross":
        hx = common.rms_norm(p["lnx"], x, cfg.norm_eps)
        qx = common.linear(p["cross"]["wq"], hx).reshape(b, 1, hq, dh)
        n = cache["ck"].shape[1]
        o = decode_attention(qx, cache["ck"], cache["cv"],
                             jnp.asarray(n, jnp.int32))
        x = x + common.linear(p["cross"]["wo"], o.reshape(b, 1, hq * dh))
    h2 = common.rms_norm(p["ln2"], x, cfg.norm_eps)
    if kind in ("moe", "moe_swa"):
        y, _ = moe_lib.moe_ffn(p["moe"], cfg, h2)
    else:
        y = common.swiglu(p["mlp"], h2)
    return x + y, new_cache


def decode_step(cfg: ModelConfig, params, cache, token):
    """token: (B, 1) int32 -> (logits (B, V), new cache)."""
    x = jnp.take(params["embed"], token, axis=0)
    pos = cache["pos"]
    shared = params.get("shared")

    def period_body(x, xs):
        slot_params, slot_caches = xs
        new_caches = {}
        for i, kind in enumerate(cfg.pattern):
            p = shared if kind == "shared_attn" else slot_params.get(str(i))
            x, new_caches[str(i)] = block_decode(kind, p, cfg, x,
                                                 slot_caches[str(i)], pos)
        return x, new_caches

    xs = (params["slots"], cache["slots"])
    x, new_slots = jax.lax.scan(period_body, x, xs)
    x = common.rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = common.linear(params["lm_head"], x)[:, 0]
    return logits, {"slots": new_slots, "pos": pos + 1}


# ================================================================== prefill
def prefill(cfg: ModelConfig, params, tokens, aux=None,
            cache_len: Optional[int] = None, cache_dtype=jnp.bfloat16):
    """Run the sequence forward AND build a decode cache.

    Returns (logits (B, S, V), cache).  cache_len defaults to S.
    """
    b, s = tokens.shape
    cache_len = cache_len or s
    out = forward_seq(cfg, params, tokens, aux, collect_kv=True)
    n_cross = 0
    if out.get("cross_states") is not None:
        n_cross = out["cross_states"].shape[1]
    cache = init_cache(cfg, b, cache_len, cache_dtype, n_cross=n_cross)

    new_slots = {}
    for i, kind in enumerate(cfg.pattern):
        piece = cache["slots"][str(i)]
        if kind not in ATTN_KINDS:
            # recurrent blocks: exact final states from the seq scan
            new_slots[str(i)] = jax.tree_util.tree_map(
                lambda harvested, init: harvested.astype(init.dtype),
                out["kv"][str(i)], piece)
            continue
        kv = out["kv"][str(i)]
        c = piece["k"].shape[2]
        take = min(s, c)
        ks, vs = kv["k"][:, :, -take:], kv["v"][:, :, -take:]
        if kind in ("swa", "moe_swa") and cfg.sliding_window and c <= s:
            # ring layout: absolute position p lives at slot p % c
            positions = jnp.arange(s - take, s)
            slots_idx = positions % c
            knew = jnp.zeros_like(piece["k"]).at[:, :, slots_idx].set(
                ks.astype(piece["k"].dtype))
            vnew = jnp.zeros_like(piece["v"]).at[:, :, slots_idx].set(
                vs.astype(piece["v"].dtype))
        else:
            knew = jax.lax.dynamic_update_slice(
                piece["k"], ks.astype(piece["k"].dtype), (0, 0, 0, 0, 0))
            vnew = jax.lax.dynamic_update_slice(
                piece["v"], vs.astype(piece["v"].dtype), (0, 0, 0, 0, 0))
        piece = dict(piece)
        piece["k"], piece["v"] = knew, vnew
        if kind == "cross":
            piece["ck"] = kv["ck"].astype(piece["ck"].dtype)
            piece["cv"] = kv["cv"].astype(piece["cv"].dtype)
        new_slots[str(i)] = piece
    cache = {"slots": new_slots, "pos": jnp.asarray(s, jnp.int32)}
    return out["logits"], cache
