"""GQA attention: full-causal, sliding-window, bidirectional and cross.

The sequence path uses a *chunked online-softmax* formulation (lax.scan over
KV blocks) so peak activation memory is O(S * block) instead of O(S^2) —
this is the XLA twin of the Pallas flash-attention kernel in
``repro/kernels/flash_attention.py`` and is what the multi-pod dry-run
lowers (Pallas has no CPU lowering path).

GQA is expressed as a grouped einsum — queries are reshaped to
(B, S, Hkv, G, Dh) and contracted directly against the (B, S, Hkv, Dh)
keys/values.  The repeated-KV tensor is never materialised: this keeps the
decode KV cache shardable on its head dim without GSPMD "involuntary full
rematerialization" copies (observed when broadcasting sharded KV heads).

Decode path attends one query position against a pre-allocated KV cache
(ring buffer for sliding-window attention).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _group_q(q: jnp.ndarray, hkv: int) -> jnp.ndarray:
    """(B, S, Hq, Dh) -> (B, S, Hkv, G, Dh)."""
    b, s, hq, dh = q.shape
    return q.reshape(b, s, hkv, hq // hkv, dh)


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      causal: bool, sliding_window: int = 0,
                      block: int = 512,
                      q_positions: Optional[jnp.ndarray] = None,
                      kv_positions: Optional[jnp.ndarray] = None
                      ) -> jnp.ndarray:
    """Online-softmax attention over KV blocks.

    q: (B, Sq, Hq, Dh); k, v: (B, Skv, Hkv, Dh).  Returns (B, Sq, Hq, Dh).
    """
    b, sq, hq, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    if q_positions is None:
        q_positions = jnp.arange(sq)
    if kv_positions is None:
        kv_positions = jnp.arange(skv)

    scale = dh ** -0.5
    qf = _group_q(q, hkv) * scale                        # (B,Sq,Hkv,G,Dh)
    g = qf.shape[3]
    block = min(block, skv)
    n_blocks = max(1, -(-skv // block))
    pad = n_blocks * block - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pad),
                               constant_values=skv + sliding_window + sq + 1)

    # storage dtype in HBM; f32 accumulation on the MXU
    kb = k.reshape(b, n_blocks, block, hkv, dh)
    vb = v.reshape(b, n_blocks, block, hkv, dh)
    pb = kv_positions.reshape(n_blocks, block)

    def body(carry, xs):
        acc, m, l = carry          # (B,Sq,Hkv,G,Dh), (B,Sq,Hkv,G), (same)
        kblk, vblk, pos = xs       # (B,block,Hkv,Dh), (block,)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, kblk,
                       preferred_element_type=jnp.float32)
        mask = jnp.ones((sq, block), bool)
        if causal:
            mask &= q_positions[:, None] >= pos[None, :]
        if sliding_window:
            mask &= q_positions[:, None] - pos[None, :] < sliding_window
        mask &= (pos < skv + sliding_window + sq)[None, :]  # padding
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + \
            jnp.einsum("bqhgk,bkhd->bqhgd", p.astype(vblk.dtype), vblk,
                       preferred_element_type=jnp.float32)
        return (acc_new, m_new, l_new), None

    init = (jnp.zeros((b, sq, hkv, g, dh), jnp.float32),
            jnp.full((b, sq, hkv, g), NEG_INF, jnp.float32),
            jnp.zeros((b, sq, hkv, g), jnp.float32))
    if n_blocks == 1:
        (acc, m, l), _ = body(init, (kb[:, 0], vb[:, 0], pb[0]))
    else:
        (acc, m, l), _ = jax.lax.scan(
            body, init, (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), pb))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, sq, hq, dh).astype(q.dtype)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, pos: jnp.ndarray, *,
                     sliding_window: int = 0,
                     cache_positions: Optional[jnp.ndarray] = None
                     ) -> jnp.ndarray:
    """One-token attention against a cache.

    q: (B, 1, Hq, Dh); caches: (B, C, Hkv, Dh); pos: scalar current position.
    For SWA the cache is a ring buffer of size C == window and
    ``cache_positions`` (C,) holds each slot's absolute position
    (-1 marks an unwritten slot).
    """
    b, _, hq, dh = q.shape
    c, hkv = k_cache.shape[1], k_cache.shape[2]
    # keep the cache in its storage dtype; accumulate the dot in f32
    # (an explicit .astype(f32) makes XLA materialise a full f32 copy of
    # the cache outside the decode loop — 2x HBM traffic for nothing)
    qf = _group_q(q, hkv) * dh ** -0.5
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, k_cache,
                   preferred_element_type=jnp.float32)  # (B,1,Hkv,G,C)
    if cache_positions is None:
        cache_positions = jnp.arange(c)
    valid = (cache_positions >= 0) & (cache_positions <= pos)
    if sliding_window:
        valid &= pos - cache_positions < sliding_window
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, hq, dh).astype(q.dtype)
