"""Adaptive KL controller (paper §5: adaptive KL with target 0.03;
TRL-style proportional controller)."""
from __future__ import annotations

import jax.numpy as jnp


def adaptive_kl_update(kl_coef: jnp.ndarray, observed_kl: jnp.ndarray,
                       target: float, horizon: float = 64.0) -> jnp.ndarray:
    """coef ← coef · (1 + clip(err, ±0.2)/horizon·...) — TRL AdaptiveKLController."""
    err = jnp.clip(observed_kl / jnp.maximum(target, 1e-8) - 1.0, -0.2, 0.2)
    mult = 1.0 + err * (1.0 / horizon) * 64.0
    return jnp.clip(kl_coef * mult, 1e-4, 10.0)
