from repro.rlhf import critic, kl, local, ppo, rewards, sampling  # noqa

__all__ = ["ppo", "critic", "rewards", "kl", "sampling", "local"]
