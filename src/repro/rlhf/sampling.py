"""Autoregressive response generation with the decode cache.

Used by the federated simulation engine and examples (toy scale, CPU).
The behaviour policy's per-token logprobs are recorded so PPO sees the
exact old_logprobs of the sampling distribution.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer


@partial(jax.jit, static_argnames=("cfg", "max_new", "temperature"))
def generate(cfg: ModelConfig, params, prompt: jnp.ndarray, key,
             max_new: int = 32, temperature: float = 1.0,
             aux: Optional[dict] = None):
    """prompt: (B, P) -> (tokens (B, P+max_new), logprobs (B, P+max_new)).

    logprobs are the sampling logprobs for generated positions, 0 elsewhere.
    """
    b, p = prompt.shape
    total = p + max_new
    _, cache = transformer.prefill(cfg, params, prompt, aux,
                                   cache_len=total)
    last = prompt[:, -1:]

    def step(carry, k):
        cache, tok = carry
        logits, cache = transformer.decode_step(cfg, params, cache, tok)
        logits = logits.astype(jnp.float32) / max(temperature, 1e-6)
        nxt = jax.random.categorical(k, logits, axis=-1)      # (B,)
        lp = jnp.take_along_axis(jax.nn.log_softmax(logits, -1),
                                 nxt[:, None], axis=-1)[:, 0]
        return (cache, nxt[:, None]), (nxt, lp)

    keys = jax.random.split(key, max_new)
    (_, _), (new_toks, new_lps) = jax.lax.scan(step, (cache, last), keys)
    new_toks = jnp.moveaxis(new_toks, 0, 1)                   # (B, max_new)
    new_lps = jnp.moveaxis(new_lps, 0, 1)
    tokens = jnp.concatenate([prompt, new_toks], axis=1)
    logprobs = jnp.concatenate([jnp.zeros((b, p), jnp.float32), new_lps],
                               axis=1)
    mask = jnp.concatenate([jnp.zeros((b, p), jnp.float32),
                            jnp.ones((b, max_new), jnp.float32)], axis=1)
    return tokens, logprobs, mask


def generate_stacked(cfg: ModelConfig, params, prompts: jnp.ndarray, keys,
                     max_new: int = 32, temperature: float = 1.0,
                     aux: Optional[dict] = None):
    """Multi-client batched generation: one dispatch for a (C, B, P) block.

    ``params`` is a stacked pytree with a leading client axis, ``keys`` is
    (C, 2) — one PRNG key per client so every client's rollout matches the
    per-client ``generate`` call with the same key.  Returns stacked
    (C, B, S) tokens / logprobs / mask.
    """

    def one(p, prompt, key):
        return generate(cfg, p, prompt, key, max_new=max_new,
                        temperature=temperature, aux=aux)

    return jax.vmap(one)(params, prompts, keys)
