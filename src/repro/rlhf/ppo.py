"""Multi-objective PPO: M per-objective clipped-PPO gradients from ONE
shared forward pass (paper Alg. 1 lines 6-9).

The paper computes M separate PPO gradients; naively that is M full
forward+backward passes.  Beyond-paper optimisation (EXPERIMENTS §Perf):
the M losses share every forward intermediate, so we take a single
``jax.vjp`` of the stacked (M,) loss vector and pull M one-hot cotangents
through it — one forward + one linearization, M (cheap, shared-remat)
transposes.

Advantages follow TFIRM's TD/GAE construction: per-token shaped rewards
are  −kl_coef·KL(π‖π_ref)  at every response token plus the terminal
reward-model score r_j at the final response position (standard RLHF
shaping, TRL-compatible).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import FIRMConfig, ModelConfig
from repro.models import transformer
from repro.models.common import merge_trainable
from repro.rlhf import critic as critic_lib


class PPOBatch(NamedTuple):
    tokens: jnp.ndarray          # (B, S) int32 prompt+response
    response_mask: jnp.ndarray   # (B, S) f32: 1 on response positions
    old_logprobs: jnp.ndarray    # (B, S) f32 behaviour-policy logprobs
    ref_logprobs: jnp.ndarray    # (B, S) f32 frozen reference logprobs
    rewards: jnp.ndarray         # (B, M) f32 sequence-level RM scores


def token_logprobs(logits: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    """logprob of tokens[t] under logits[t-1]; position 0 gets 0.

    Returns (B, S) aligned with ``tokens``/masks.
    """
    lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    lp_tok = jnp.take_along_axis(lp, tokens[:, 1:, None], axis=-1)[..., 0]
    return jnp.pad(lp_tok, ((0, 0), (1, 0)))


def shaped_rewards(kl: jnp.ndarray, mask: jnp.ndarray, rewards: jnp.ndarray,
                   kl_coef: jnp.ndarray) -> jnp.ndarray:
    """(B,S) kl, (B,S) mask, (B,M) terminal -> (B,S,M) per-token rewards."""
    # last response position per row
    idx = jnp.maximum(mask.sum(-1) - 1, 0).astype(jnp.int32)
    last = jax.nn.one_hot(
        (jnp.argmax(mask * jnp.arange(mask.shape[1])[None], axis=-1)),
        mask.shape[1], dtype=jnp.float32)                     # (B, S)
    del idx
    r = -kl_coef * kl[..., None] * mask[..., None]
    r = r + last[..., None] * rewards[:, None, :]
    return r


def gae(rewards_tok: jnp.ndarray, values: jnp.ndarray, mask: jnp.ndarray,
        gamma: float, lam: float):
    """(B,S,M) rewards, (B,S,M) values -> (advantages, returns)."""
    next_mask = jnp.concatenate([mask[:, 1:], jnp.zeros_like(mask[:, :1])],
                                axis=1)[..., None]
    v_next = jnp.concatenate([values[:, 1:], jnp.zeros_like(values[:, :1])],
                             axis=1)
    delta = rewards_tok + gamma * v_next * next_mask - values

    def body(carry, xs):
        d, nm = xs
        adv = d + gamma * lam * nm * carry
        return adv, adv

    ds = jnp.moveaxis(delta, 1, 0)[::-1]                     # (S, B, M)
    nms = jnp.moveaxis(next_mask, 1, 0)[::-1]
    _, advs = jax.lax.scan(body, jnp.zeros_like(ds[0]), (ds, nms))
    adv = jnp.moveaxis(advs[::-1], 0, 1)                     # (B, S, M)
    return adv, adv + values


def masked_mean(x, mask):
    return (x * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def multi_objective_losses(cfg: ModelConfig, fc: FIRMConfig, trainable,
                           frozen, critic, batch: PPOBatch, kl_coef,
                           aux: Optional[dict] = None):
    """Stacked (M,) PPO losses + auxiliary outputs (single forward)."""
    params = merge_trainable(trainable, frozen)
    out = transformer.forward_seq(cfg, params, batch.tokens, aux)
    lp = token_logprobs(out["logits"], batch.tokens)
    mask = batch.response_mask
    ratio = jnp.exp(jnp.clip(lp - batch.old_logprobs, -20.0, 20.0))
    kl = lp - batch.ref_logprobs

    feats = critic_lib.features(out["hidden"])
    vals = critic_lib.values(critic, feats)                  # (B, S, M)
    r_tok = shaped_rewards(jax.lax.stop_gradient(kl), mask, batch.rewards,
                           kl_coef)
    adv, rets = gae(jax.lax.stop_gradient(r_tok),
                    jax.lax.stop_gradient(vals), mask,
                    fc.gamma, fc.gae_lambda)
    # per-objective advantage whitening over response tokens
    mean = (adv * mask[..., None]).sum((0, 1)) / jnp.maximum(
        mask.sum(), 1.0)
    var = (((adv - mean) ** 2) * mask[..., None]).sum((0, 1)) / jnp.maximum(
        mask.sum(), 1.0)
    adv = (adv - mean) / jnp.sqrt(var + 1e-8)

    clipped = jnp.clip(ratio, 1.0 - fc.ppo_clip, 1.0 + fc.ppo_clip)
    pg = -jnp.minimum(ratio[..., None] * adv, clipped[..., None] * adv)
    losses = (pg * mask[..., None]).sum((0, 1)) / jnp.maximum(mask.sum(), 1.0)
    losses = losses + out["aux_loss"]                        # MoE router aux

    metrics = {
        "kl": masked_mean(kl, mask),
        "ratio_mean": masked_mean(ratio, mask),
        "entropy_proxy": -masked_mean(lp, mask),
        "aux_loss": out["aux_loss"],
    }
    return losses, (metrics, feats, r_tok, rets, mask)


def per_objective_grads(cfg: ModelConfig, fc: FIRMConfig, trainable, frozen,
                        critic, batch: PPOBatch, kl_coef,
                        aux: Optional[dict] = None):
    """M gradients of the M losses w.r.t. ``trainable`` — one forward.

    Returns (grads: list of M pytrees, losses (M,), extras).

    With ``cfg.batched_vjp`` the M cotangent pulls are vmapped: under
    remat the sequential pulls each re-run the rematerialised forward,
    while the vmapped transpose shares ONE recompute across objectives
    (EXPERIMENTS §Perf hillclimb — ~(M-1) forward-equivalents saved).
    """
    m = fc.n_objectives

    def fn(tr):
        return multi_objective_losses(cfg, fc, tr, frozen, critic, batch,
                                      kl_coef, aux)

    (losses, extras), vjp_fn = jax.vjp(fn, trainable, has_aux=False)
    # vjp over the tuple output: cotangent for extras must be zeros
    zeros_extras = jax.tree_util.tree_map(jnp.zeros_like, extras)
    if cfg.batched_vjp:
        stacked = jax.vmap(lambda e: vjp_fn((e, zeros_extras))[0])(
            jnp.eye(m, dtype=losses.dtype))
        grads = [jax.tree_util.tree_map(lambda l, j=j: l[j], stacked)
                 for j in range(m)]
    else:
        grads = []
        for j in range(m):
            ct = (jax.nn.one_hot(j, m, dtype=losses.dtype), zeros_extras)
            grads.append(vjp_fn(ct)[0])
    return grads, losses, extras
