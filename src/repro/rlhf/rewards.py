"""Synthetic reward models (DESIGN §5 — the HF reward models are a data
gate at repro band 2; we replace them with jittable proxies whose
*conflict structure* mirrors helpfulness-vs-harmlessness).

Token-band construction: helpfulness rewards response tokens inside a
"helpful" id band that OVERLAPS a "harmful" band, so pushing helpfulness
up drags harmlessness down — the same tension the paper's Fig. 2-4
navigate.  Conciseness linearly penalises length beyond a tolerance
(paper A.2.3).  All rewards are normalised to [0, 1] (paper §5).

A second parameterisation (`variant="alt"`) shifts the bands — used for
the heterogeneous-reward-model experiment (paper A.2.1), standing in for
the OpenAssistant/deberta RM.
"""
from __future__ import annotations

from typing import Callable, Dict, Sequence

import jax
import jax.numpy as jnp


def _band(vocab: int, lo: float, hi: float):
    return int(vocab * lo), int(vocab * hi)


# ------------------------------------------------------------- banded cores
# The reward math lives in functions parameterized by the band edges so the
# vectorized round engine can vmap one scorer over a stacked client axis
# with per-client bands (heterogeneous RMs) instead of dispatching per-client
# Python closures.  ``make_reward_fns`` builds its closures on the same
# cores, so both engine paths share the exact arithmetic.

def _frac_in_band(tokens: jnp.ndarray, mask: jnp.ndarray,
                  band) -> jnp.ndarray:
    inb = ((tokens >= band[0]) & (tokens < band[1])).astype(jnp.float32)
    n = jnp.maximum(mask.sum(-1), 1.0)
    return (inb * mask).sum(-1) / n


def helpfulness_reward(tokens, mask, band):
    # concave in the helpful fraction: diminishing returns, in [0,1]
    f = _frac_in_band(tokens, mask, band)
    return jnp.sqrt(jnp.clip(f, 0.0, 1.0))


def harmlessness_reward(tokens, mask, band):
    f = _frac_in_band(tokens, mask, band)
    return jnp.clip(1.0 - 2.0 * f, 0.0, 1.0)


def conciseness_reward(tokens, mask, length_tolerance: int):
    # length penalty (paper A.2.3) + anti-redundancy: the simulation
    # generates fixed-length responses, so pure length is constant —
    # the distinct-token fraction gives the policy a live signal with
    # the same "don't pad/ramble" semantics.
    n = mask.sum(-1)
    over = jnp.maximum(n - length_tolerance, 0.0)
    length_term = jnp.clip(
        1.0 - over / jnp.maximum(length_tolerance, 1.0), 0.0, 1.0)
    tok = jnp.where(mask > 0, tokens, -1)
    same = (tok[:, :, None] == tok[:, None, :]) & \
        (tok[:, :, None] >= 0)
    repeats = same.sum(-1).astype(jnp.float32)            # (B, S)
    distinct = (mask / jnp.maximum(repeats, 1.0)).sum(-1) / \
        jnp.maximum(n, 1.0)
    return jnp.clip(0.5 * length_term + 0.5 * distinct, 0.0, 1.0)


def variant_bands(vocab: int, variant: str = "default"):
    """(helpful, harmful) band edges as (2,) int32 arrays — the traced
    per-client reward parameters of the vectorized scorer."""
    if variant == "alt":
        helpful = _band(vocab, 0.30, 0.55)
        harmful = _band(vocab, 0.42, 0.60)
    else:
        helpful = _band(vocab, 0.25, 0.50)
        harmful = _band(vocab, 0.45, 0.55)
    return (jnp.asarray(helpful, jnp.int32), jnp.asarray(harmful, jnp.int32))


def make_reward_fns(vocab: int, n_objectives: int = 2,
                    variant: str = "default",
                    length_tolerance: int = 24) -> Sequence[Callable]:
    """Returns M callables (tokens, mask) -> (B,) rewards in [0, 1].

    tokens: (B, S) response tokens; mask: (B, S) 1.0 on response positions.
    """
    helpful, harmful = variant_bands(vocab, variant)

    def helpfulness(tokens, mask):
        return helpfulness_reward(tokens, mask, helpful)

    def harmlessness(tokens, mask):
        return harmlessness_reward(tokens, mask, harmful)

    def conciseness(tokens, mask):
        return conciseness_reward(tokens, mask, length_tolerance)

    fns = [helpfulness, harmlessness, conciseness]
    if n_objectives > len(fns):
        raise ValueError(f"at most {len(fns)} synthetic objectives")
    return fns[:n_objectives]


def score_batch(reward_fns: Sequence[Callable], tokens: jnp.ndarray,
                mask: jnp.ndarray) -> jnp.ndarray:
    """(B, S) tokens/mask -> (B, M) rewards."""
    return jnp.stack([f(tokens, mask) for f in reward_fns], axis=-1)


def score_batch_banded(helpful: jnp.ndarray, harmful: jnp.ndarray,
                       tokens: jnp.ndarray, mask: jnp.ndarray,
                       n_objectives: int,
                       length_tolerance: int) -> jnp.ndarray:
    """Band-parameterized twin of ``score_batch``: (B, S) -> (B, M).

    ``helpful``/``harmful`` are (2,) int32 band edges (``variant_bands``);
    vmap over a leading client axis of (C, 2) bands scores every client's
    rollouts in one dispatch, including heterogeneous-RM sweeps.
    """
    cols = [helpfulness_reward(tokens, mask, helpful),
            harmlessness_reward(tokens, mask, harmful),
            conciseness_reward(tokens, mask, length_tolerance)]
    if n_objectives > len(cols):
        raise ValueError(f"at most {len(cols)} synthetic objectives")
    return jnp.stack(cols[:n_objectives], axis=-1)


# ---------------------------------------------------------------- learned RM
def init_learned_rm(key, vocab: int, d: int = 64):
    """A tiny fixed (frozen) scoring head: mean embedding -> scalar.

    Stands in for a learned reward model with an arbitrary preference
    direction; used in robustness experiments.
    """
    k1, k2 = jax.random.split(key)
    return {"embed": jax.random.normal(k1, (vocab, d)) * 0.05,
            "w": jax.random.normal(k2, (d,)) * 0.3}


def learned_rm_score(p, tokens, mask):
    e = p["embed"][tokens]                                   # (B, S, d)
    m = mask[..., None]
    pooled = (e * m).sum(1) / jnp.maximum(m.sum(1), 1.0)
    return jax.nn.sigmoid(pooled @ p["w"])                    # (B,) in [0,1]
