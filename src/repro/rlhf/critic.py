"""TFIRM critics: M linear value functions on a shared feature map
(paper Assumption 4.2 / Algorithm 3).

φ(s) = stop-gradient(normalised last hidden state), ||φ|| ≤ 1 by
construction (Assumption 4.2b).  Each objective j has w_j ∈ R^{d}, trained
by mini-batch TD with a projection onto the ball of radius R_w (Alg. 3
line 12).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_critic(m: int, d: int):
    return {"w": jnp.zeros((m, d), jnp.float32)}


def features(hidden: jnp.ndarray) -> jnp.ndarray:
    """(B, S, d) hidden -> normalised features with ||φ|| ≤ 1."""
    h = jax.lax.stop_gradient(hidden.astype(jnp.float32))
    return h / jnp.maximum(jnp.linalg.norm(h, axis=-1, keepdims=True), 1.0)


def values(critic, feats: jnp.ndarray) -> jnp.ndarray:
    """(B, S, d) -> (B, S, M)."""
    return jnp.einsum("bsd,md->bsm", feats, critic["w"])


def project(critic, r_w: float):
    """Π_H: scale each w_j back into the R_w ball (Alg. 3, closed form)."""
    n = jnp.linalg.norm(critic["w"], axis=-1, keepdims=True)
    scale = jnp.minimum(1.0, r_w / jnp.maximum(n, 1e-12))
    return {"w": critic["w"] * scale}


def td_update(critic, feats: jnp.ndarray, rewards_tok: jnp.ndarray,
              mask: jnp.ndarray, gamma: float, lr: float, r_w: float):
    """One mini-batch TD step for all M critics (Alg. 3 line 11).

    feats: (B, S, d); rewards_tok: (B, S, M) per-token shaped rewards;
    mask: (B, S) response mask.  δ_t = r_t + γ φ(s_{t+1})ᵀw − φ(s_t)ᵀw.
    """
    v = values(critic, feats)                                # (B, S, M)
    v_next = jnp.concatenate([v[:, 1:], jnp.zeros_like(v[:, :1])], axis=1)
    # mask the bootstrap at sequence end
    next_mask = jnp.concatenate([mask[:, 1:], jnp.zeros_like(mask[:, :1])],
                                axis=1)
    delta = rewards_tok + gamma * v_next * next_mask[..., None] - v
    delta = delta * mask[..., None]
    n = jnp.maximum(mask.sum(), 1.0)
    grad = jnp.einsum("bsm,bsd->md", delta, feats) / n
    new = {"w": critic["w"] + lr * grad}                     # TD ascent on δφ
    return project(new, r_w), jnp.mean(jnp.abs(delta))


def r_w_bound(r_max: float, lambda_a: float = 0.1) -> float:
    """R_w = 2 r_max / λ_A (App. C)."""
    return 2.0 * r_max / lambda_a
