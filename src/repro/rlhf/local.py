"""The FIRM client-local update step (Alg. 1, inner loop body).

``firm_local_step`` is the jittable unit of work the framework runs
everywhere: the federated simulation engine executes it per client on CPU,
and the multi-pod dry-run lowers it at full scale under the production
mesh (each pod = one client; see launch/steps.py).

Pipeline: multi-objective PPO grads (one forward, M pulls) -> in-client
regularized MGDA resolve (Eq. 1) -> Adam on the adapters -> TD update of
the M linear critics -> adaptive-KL bookkeeping.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import FIRMConfig, ModelConfig
from repro.core import fedcmoo, firm
from repro.rlhf import critic as critic_lib
from repro.rlhf import kl as kl_lib
from repro.rlhf import ppo
from repro.train import optim


class ClientState(NamedTuple):
    trainable: object            # LoRA adapters (or full params)
    critic: dict                 # M linear value heads
    opt: optim.AdamState
    lam: jnp.ndarray             # smoothed MGDA weights (M,)
    kl_coef: jnp.ndarray
    step: jnp.ndarray            # local+global step counter (for η_t)


def init_client_state(trainable, m: int, d_model: int,
                      kl_coef: float = 0.1) -> ClientState:
    return ClientState(
        trainable=trainable,
        critic=critic_lib.init_critic(m, d_model),
        opt=optim.adam_init(trainable),
        lam=jnp.full((m,), 1.0 / m, jnp.float32),
        kl_coef=jnp.asarray(kl_coef, jnp.float32),
        step=jnp.zeros((), jnp.int32),
    )


def firm_local_step(cfg: ModelConfig, fc: FIRMConfig, state: ClientState,
                    frozen, batch: ppo.PPOBatch,
                    aux: Optional[dict] = None, gram_fn=None,
                    preference=None):
    """One local FIRM update.  Returns (new_state, metrics).

    ``preference`` optionally overrides ``fc.preference`` with a traced
    (M,) array — the vmap-safe signature the vectorized engine uses to run
    heterogeneous per-client preferences through a single trace.
    """
    grads, losses, (metrics, feats, r_tok, rets, mask) = \
        ppo.per_objective_grads(cfg, fc, state.trainable, frozen,
                                state.critic, batch, state.kl_coef, aux)
    eta = firm.eta_schedule(state.step + 1) if fc.lambda_smoothing else None
    res = firm.resolve(grads, fc, prev_lam=state.lam, eta=eta,
                       gram_fn=gram_fn, preference=preference)
    new_trainable, new_opt, gnorm = optim.adam_update(
        res.direction, state.opt, state.trainable, lr=fc.actor_lr,
        max_grad_norm=1.0)
    r_w = critic_lib.r_w_bound(r_max=1.0)
    new_critic, td_err = critic_lib.td_update(
        state.critic, feats, r_tok, mask, fc.gamma, fc.critic_lr, r_w)
    new_kl = kl_lib.adaptive_kl_update(state.kl_coef, metrics["kl"],
                                       fc.kl_target)
    new_state = ClientState(new_trainable, new_critic, new_opt, res.lam,
                            new_kl, state.step + 1)
    metrics = dict(metrics, losses=losses, lam=res.lam,
                   lam_star=res.lam_star, gram=res.gram, grad_norm=gnorm,
                   td_err=td_err, rewards=batch.rewards.mean(0))
    return new_state, metrics


def fedcmoo_local_grads(cfg: ModelConfig, fc: FIRMConfig,
                        state: ClientState, frozen, batch: ppo.PPOBatch,
                        aux: Optional[dict] = None):
    """FedCMOO client phase 1: compute and 'transmit' the M gradients."""
    grads, losses, (metrics, feats, r_tok, rets, mask) = \
        ppo.per_objective_grads(cfg, fc, state.trainable, frozen,
                                state.critic, batch, state.kl_coef, aux)
    return grads, losses, (metrics, feats, r_tok, mask)


def fedcmoo_local_apply(fc: FIRMConfig, state: ClientState, grads,
                        lam: jnp.ndarray, extras):
    """FedCMOO client phase 2: apply the server-broadcast λ."""
    metrics, feats, r_tok, mask = extras
    direction = firm.mgda.combine(grads, lam)
    new_trainable, new_opt, gnorm = optim.adam_update(
        direction, state.opt, state.trainable, lr=fc.actor_lr,
        max_grad_norm=1.0)
    r_w = critic_lib.r_w_bound(r_max=1.0)
    new_critic, td_err = critic_lib.td_update(
        state.critic, feats, r_tok, mask, fc.gamma, fc.critic_lr, r_w)
    new_kl = kl_lib.adaptive_kl_update(state.kl_coef, metrics["kl"],
                                       fc.kl_target)
    new_state = ClientState(new_trainable, new_critic, new_opt, lam,
                            new_kl, state.step + 1)
    return new_state, dict(metrics, lam=lam, grad_norm=gnorm, td_err=td_err)


def linear_local_step(cfg: ModelConfig, fc: FIRMConfig, state: ClientState,
                      frozen, batch: ppo.PPOBatch, weights: jnp.ndarray,
                      aux: Optional[dict] = None):
    """Fixed-weight linear scalarization step (the implicit RQ1 baseline).

    Fuses ``fedcmoo_local_grads`` + ``fedcmoo_local_apply`` with a constant
    λ = ``weights`` so the vectorized engine can scan it as one jittable
    unit; the math is exactly the loop path's two-phase call sequence.
    """
    grads, losses, extras = fedcmoo_local_grads(cfg, fc, state, frozen,
                                                batch, aux)
    new_state, metrics = fedcmoo_local_apply(fc, state, grads, weights,
                                             extras)
    return new_state, dict(metrics, losses=losses,
                           rewards=batch.rewards.mean(0))
