"""Codec protocol + payload container for federated uplink/downlink traffic.

A ``Codec`` turns a param/delta pytree into a ``Payload`` — a bag of
*actually transmitted* arrays whose ``nbytes`` is measured from the buffer
dtypes (int8 codes count 1 byte, packed int4 nibbles half a byte, ...),
replacing the old f32-only ``tree_param_bytes`` assumption — and back.

Codecs are stateless objects; per-client compression state (the error
feedback residual) is threaded explicitly through ``encode`` so one codec
instance serves every client while residuals stay client-local:

    payload, state = codec.encode(tree, state, key=key)
    tree2 = codec.decode(payload)

``ErrorFeedback`` wraps any lossy codec: the client adds its accumulated
residual before encoding and keeps the new residual (x + e) - decode(...)
locally, so quantization/sparsification error is re-injected instead of
lost — the standard EF trick that restores convergence under biased
compressors (cf. PowerSGD / EF-SGD).

Traced codec contract (fused multi-round engine)
------------------------------------------------
Next to the host-boundary ``Payload`` API every codec exposes a fully
in-graph path the fused round scan uses:

* ``roundtrip_traced(flat, state, key)`` -> (decoded, new_state) keeps
  encode -> decode entirely inside the surrounding jit — the Payload
  buffers are graph intermediates that never reach the host;
* ``roundtrip_traced_stacked(flats, states, keys)`` is its (C, d)
  stacked-client twin (quantize codecs batch ONE kernel over all rows);
* codec state is an explicit pytree of arrays so it can ride a
  ``lax.scan`` carry: ``init_state_traced`` / ``init_states_traced``
  build it from the host-format state (None -> zeros — equivalent by
  construction), ``state_to_host`` / ``states_to_host`` convert back;
* ``nbytes_static(d)`` is the exact wire size of one payload for a
  d-element flat vector.  Every shipped codec has data-INdependent
  payload sizes (codes/scales/index/value buffer shapes are functions of
  d alone), so the comms ledger and the scheduler's time models keep
  exact byte accounting without a device->host sync per round.
  ``tests/test_fed_fused.py`` pins ``nbytes_static == Payload.nbytes``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Payload:
    """What actually crosses the wire: named buffers + static metadata.

    ``meta`` (treedef, shapes, codec params) is O(#leaves) python data —
    negligible next to the O(d) buffers and excluded from the byte count.
    """
    kind: str
    arrays: Dict[str, jnp.ndarray]
    meta: Dict[str, Any]

    @property
    def nbytes(self) -> int:
        return int(sum(a.size * a.dtype.itemsize
                       for a in self.arrays.values()))

    @property
    def nbytes_entropy(self) -> int:
        """Size estimate under an ideal entropy coder (host-side, lazy).

        The discrete code buffers are charged their empirical zeroth-order
        entropy instead of their fixed-width layout — int4/topk codes are
        far from uniform, so this quantifies the headroom a real range
        coder would buy.  f32 side buffers (scales, kept values, sketch
        factors) stay at their raw size; codecs whose buffers are all f32
        report ``nbytes`` unchanged.
        """
        bits = self.meta.get("bits")
        if bits in (4, 8):
            codes = np.asarray(self.arrays["codes"])
            if bits == 4:                 # nibble symbols, not packed bytes
                u = codes.astype(np.uint8)
                codes = np.concatenate([u >> 4, u & 0xF], axis=None)
            code_bytes = -(-_entropy_total_bits(codes) // 8)
            return int(code_bytes + self.arrays["scales"].size
                       * self.arrays["scales"].dtype.itemsize)
        if "indices" in self.arrays:      # topk: gap-coded sorted indices
            idx = np.asarray(self.arrays["indices"], np.int64)
            gaps = np.diff(idx, prepend=0)
            idx_bytes = -(-_entropy_total_bits(gaps) // 8)
            vals = self.arrays["values"]
            return int(idx_bytes + vals.size * vals.dtype.itemsize)
        return self.nbytes


def _entropy_total_bits(symbols) -> int:
    """Total bits of a symbol array under its empirical distribution."""
    _, counts = np.unique(np.asarray(symbols).ravel(), return_counts=True)
    p = counts / counts.sum()
    return int(np.ceil(float(-(p * np.log2(p)).sum()) * counts.sum()))


@dataclasses.dataclass(frozen=True)
class TreeSpec:
    """Enough structure to rebuild a pytree from a flat f32 vector."""
    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]

    @property
    def size(self) -> int:
        out = 0
        for s in self.shapes:
            n = 1
            for x in s:
                n *= x
            out += n
        return out


def tree_to_flat(tree) -> Tuple[jnp.ndarray, TreeSpec]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    spec = TreeSpec(treedef, tuple(l.shape for l in leaves),
                    tuple(l.dtype for l in leaves))
    flat = jnp.concatenate([l.astype(jnp.float32).reshape(-1)
                            for l in leaves])
    return flat, spec


def flat_to_tree(flat: jnp.ndarray, spec: TreeSpec):
    leaves, off = [], 0
    for shape, dtype in zip(spec.shapes, spec.dtypes):
        n = 1
        for s in shape:
            n *= s
        leaves.append(flat[off:off + n].reshape(shape).astype(dtype))
        off += n
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)


class Codec:
    """Base codec: subclasses implement the flat-vector transform."""

    name = "codec"
    stateful = False
    # the flat-vector transform is pure jnp (jit-safe), so the fused
    # round scan may inline encode->decode via the traced API below
    traceable = True

    # -- flat-vector transform (override) -------------------------------
    def encode_flat(self, flat: jnp.ndarray, *, key=None
                    ) -> Tuple[Dict[str, jnp.ndarray], Dict[str, Any]]:
        raise NotImplementedError

    def decode_flat(self, payload: Payload) -> jnp.ndarray:
        raise NotImplementedError

    def bits_per_param(self, d: int) -> float:
        """Analytic uplink cost model (exact for the buffer layout)."""
        raise NotImplementedError

    def nbytes_static(self, d: int) -> int:
        """Exact wire bytes of one payload for a d-element flat vector.

        All shipped codecs have data-independent payload sizes, so this
        equals ``Payload.nbytes`` without materializing a payload — the
        fused multi-round engine accounts bytes from it with zero host
        syncs.  Subclasses whose layout differs from a pure
        bits-per-param model (padding, per-block scales) override it.
        """
        raise NotImplementedError

    def meta_static(self, d: int) -> Dict[str, Any]:
        """The ``encode_flat`` meta dict for a d-element flat vector.

        Shipped codecs' meta is a pure function of d and the codec
        params (like ``nbytes_static``), which lets ``ErrorFeedback``
        rebuild exact Payloads from in-graph encode outputs without a
        second host-side encode.  Codecs whose ``encode_flat`` attaches
        meta must override this to match it.
        """
        return {}

    def _flat_payload(self, flat: jnp.ndarray, spec: "TreeSpec", *,
                      key=None) -> Payload:
        arrays, meta = self.encode_flat(flat, key=key)
        meta["spec"] = spec
        meta["d"] = int(flat.size)
        return Payload(self.name, arrays, meta)

    # -- pytree API -----------------------------------------------------
    def encode(self, tree, state=None, *, key=None
               ) -> Tuple[Payload, Optional[Any]]:
        flat, spec = tree_to_flat(tree)
        return self._flat_payload(flat, spec, key=key), state

    def decode(self, payload: Payload):
        flat = self.decode_flat(payload)[:payload.meta["d"]]
        return flat_to_tree(flat, payload.meta["spec"])

    def roundtrip(self, tree, state=None, *, key=None):
        """encode + what the receiver will decode, in one call.

        Returns (payload, new_state, decoded_tree).  ErrorFeedback
        overrides this to reuse the decode it already computed for the
        residual instead of running a second O(d) decode.
        """
        payload, new_state = self.encode(tree, state, key=key)
        return payload, new_state, self.decode(payload)

    # -- pre-flattened API ----------------------------------------------
    def roundtrip_flat(self, flat: jnp.ndarray, spec: "TreeSpec",
                       state=None, *, key=None):
        """Per-client Payload boundary for pre-flattened uplinks.

        The vectorized engine flattens all C client deltas in ONE batched
        tree op and hands each codec a (d,) f32 row plus the shared
        ``TreeSpec``, skipping C per-client ``tree_to_flat``/
        ``flat_to_tree`` passes.  Returns (payload, new_state,
        decoded_flat) — byte-identical payloads to ``roundtrip``.
        """
        payload = self._flat_payload(flat, spec, key=key)
        return payload, state, self.decode_flat(payload)[:flat.size]

    # -- stacked-client API ---------------------------------------------
    def encode_stacked(self, flats: jnp.ndarray, spec: "TreeSpec",
                       states=None, *, keys=None):
        """Encode all C client rows of a (C, d) stacked flat array.

        Returns (payloads, new_states) — one Payload per client,
        byte-identical to C per-client ``encode``/``roundtrip_flat``
        calls with the same per-client keys.  The base implementation
        loops; batch-shaped codecs (int8/int4) override it to run ONE
        kernel dispatch over the stacked axis (the cohort dispatch path).
        """
        c = flats.shape[0]
        states = list(states) if states is not None else [None] * c
        keys = list(keys) if keys is not None else [None] * c
        payloads = [self._flat_payload(flats[i], spec, key=keys[i])
                    for i in range(c)]
        return payloads, states

    def roundtrip_stacked(self, flats: jnp.ndarray, spec: "TreeSpec",
                          states=None, *, keys=None):
        """``roundtrip_flat`` over the stacked client axis.

        Returns (payloads, new_states, decoded) with decoded shaped
        (C, d).  The base implementation threads per-client state through
        C ``roundtrip_flat`` calls — exact for any codec, including
        stateful wrappers; quantize codecs override with a batched
        single-dispatch path.
        """
        c = flats.shape[0]
        states = list(states) if states is not None else [None] * c
        keys = list(keys) if keys is not None else [None] * c
        payloads, new_states, decs = [], [], []
        for i in range(c):
            p, s, d = self.roundtrip_flat(flats[i], spec, states[i],
                                          key=keys[i])
            payloads.append(p)
            new_states.append(s)
            decs.append(d)
        return payloads, new_states, jnp.stack(decs)

    # -- traced (in-graph) API -------------------------------------------
    # See the module docstring: encode -> decode stays inside the caller's
    # jit, codec state is an explicit pytree of arrays (scan-carry ready),
    # and byte accounting comes from nbytes_static instead of a payload.

    def init_state_traced(self, d: int, host_state=None):
        """Traced-state pytree for ONE stream (downlink broadcast)."""
        return ()

    def state_to_host(self, state):
        """Inverse of ``init_state_traced`` after the fused run."""
        return None

    def init_states_traced(self, d: int, host_states):
        """Stacked traced state for C client streams (uplink carry)."""
        return ()

    def states_to_host(self, states, n: int):
        return [None] * n

    def roundtrip_traced(self, flat: jnp.ndarray, state=(), *, key=None):
        """In-graph encode + decode of one (d,) flat vector.

        Returns (decoded, new_state).  The default reuses the flat-vector
        transform — exact for stateless codecs; stateful wrappers
        (ErrorFeedback / DeltaCodec) override with explicit array state.
        The intermediate Payload holds tracers and never reaches the
        host; its static meta (shapes, d) is resolved at trace time.

        Both ends of the transform sit behind an optimization barrier, a
        best-effort marker of the wire boundary (on a real wire the
        payload bits ARE materialized).  Note the barrier does NOT stop
        XLA:CPU's fma/fms contraction across it — which is why the
        consumers that need bit-parity with the host boundary (the EF
        residual, see ``ErrorFeedback``) compute their arithmetic in the
        same jitted composition on both paths instead of relying on it.
        """
        decoded, state = self._roundtrip_traced_raw(
            jax.lax.optimization_barrier(flat), state, key=key)
        return jax.lax.optimization_barrier(decoded), state

    def _roundtrip_traced_raw(self, flat, state, *, key=None):
        payload = self._flat_payload(flat, None, key=key)
        return self.decode_flat(payload)[:flat.size], state

    def encode_decode_traced(self, flat: jnp.ndarray, *, key=None):
        """In-graph encode + decode that ALSO returns the wire buffers.

        Returns (payload arrays, decoded) with the exact barrier
        placement of ``roundtrip_traced`` — the decoded value is
        bit-identical to it — plus the payload's array dict as graph
        outputs, so a caller under jit can materialize the wire bytes
        from the SAME encode that produced the decode (the single-encode
        uplink: see ``ErrorFeedback.roundtrip_flat``).
        """
        payload = self._flat_payload(jax.lax.optimization_barrier(flat),
                                     None, key=key)
        decoded = self.decode_flat(payload)[:flat.size]
        return payload.arrays, jax.lax.optimization_barrier(decoded)

    def roundtrip_traced_stacked(self, flats: jnp.ndarray, states=(), *,
                                 keys=None):
        """``roundtrip_traced`` over the stacked (C, d) client axis.

        Row c is bit-identical to ``roundtrip_traced(flats[c], ...,
        key=keys[c])``; quantize codecs override with the single batched
        kernel dispatch the host-boundary stacked path uses.  The wire
        barriers sit OUTSIDE the vmap (optimization_barrier has no
        batching rule).
        """
        def one(f, k, s):
            return self._roundtrip_traced_raw(f, s, key=k)
        decoded, states = jax.vmap(one)(
            jax.lax.optimization_barrier(flats), keys, states)
        return jax.lax.optimization_barrier(decoded), states

    def encode_decode_traced_stacked(self, flats: jnp.ndarray, *,
                                     keys=None):
        """``encode_decode_traced`` over the stacked (C, d) client axis.

        Returns (payload arrays with a leading (C,) axis, (C, d)
        decoded); decoded rows are bit-identical to
        ``roundtrip_traced_stacked``'s.  ``keys`` must be a per-client
        key array (callers with None keys take the per-row host path).
        """
        def one(f, k):
            payload = self._flat_payload(f, None, key=k)
            return payload.arrays, self.decode_flat(payload)[:f.size]
        arrays, decoded = jax.vmap(one)(
            jax.lax.optimization_barrier(flats), keys)
        return arrays, jax.lax.optimization_barrier(decoded)

    def stacked_payloads_from_arrays(self, arrays, c: int, spec: "TreeSpec",
                                     d: int):
        """Per-client Payloads from ``encode_decode_traced_stacked``'s
        array outputs (leading (C,) axis layout; batch-shaped codecs
        override to slice their concatenated-row layout)."""
        meta = self.meta_static(d)
        return [Payload(self.name, {k: v[i] for k, v in arrays.items()},
                        {**meta, "spec": spec, "d": d})
                for i in range(c)]


class IdentityCodec(Codec):
    """Raw f32 — the baseline every ratio in the benchmarks is against."""

    name = "identity"

    def encode_flat(self, flat, *, key=None):
        return {"values": flat.astype(jnp.float32)}, {}

    def decode_flat(self, payload):
        return payload.arrays["values"]

    def bits_per_param(self, d: int) -> float:
        return 32.0

    def nbytes_static(self, d: int) -> int:
        return 4 * d


class ErrorFeedback(Codec):
    """Residual-accumulating wrapper around a lossy inner codec.

    state is the client-local residual flat vector (starts at zero);
    decode is the inner codec's (the server never sees the residual).

    The whole uplink — residual add, inner encode, decode, residual
    update — runs inside ONE jitted program, for three reasons: it is
    one dispatch instead of a chain of eager ops; each uplink encodes
    exactly ONCE (the payload's wire buffers are outputs of the same
    in-graph encode that produced the decode — no eager re-encode); and
    — decisively — XLA CPU contracts the dequantize multiply into the
    residual subtract (an fms) whenever both sit in the same program,
    which no barrier prevents.  Computing the residual the same way on
    the host boundary and inside the fused round scan keeps the two
    engines bit-identical.  Payloads are rebuilt host-side from the
    returned arrays + the inner codec's static meta
    (``Codec.meta_static``), byte-identical to an eager encode.
    """

    stateful = True

    def __init__(self, inner: Codec):
        self.inner = inner
        self.name = inner.name + "+ef"
        self._rt_flat_jit = None
        self._rt_stacked_jit = None

    # jitted handles are cached per codec instance (one instance serves
    # every client of a trainer, so each trainer compiles these once)
    def _jit_rt_flat(self):
        if self._rt_flat_jit is None:
            def fn(f, s, k):
                adj = f + s
                arrays, dec = self.inner.encode_decode_traced(adj, key=k)
                return arrays, dec, adj - dec
            self._rt_flat_jit = jax.jit(fn)
        return self._rt_flat_jit

    def _jit_rt_stacked(self):
        if self._rt_stacked_jit is None:
            def fn(f, s, k):
                adj = f + s
                arrays, dec = self.inner.encode_decode_traced_stacked(
                    adj, keys=k)
                return arrays, dec, adj - dec
            self._rt_stacked_jit = jax.jit(fn)
        return self._rt_stacked_jit

    def encode(self, tree, state=None, *, key=None):
        flat, spec = tree_to_flat(tree)
        payload, residual, _ = self.roundtrip_flat(flat, spec, state,
                                                   key=key)
        return payload, residual

    def roundtrip(self, tree, state=None, *, key=None):
        flat, spec = tree_to_flat(tree)
        payload, residual, decoded = self.roundtrip_flat(flat, spec,
                                                         state, key=key)
        return payload, residual, flat_to_tree(decoded, spec)

    def roundtrip_flat(self, flat, spec, state=None, *, key=None):
        st = jnp.zeros_like(flat) if state is None else state
        arrays, decoded, residual = self._jit_rt_flat()(flat, st, key)
        d = int(flat.size)
        payload = Payload(self.inner.name, dict(arrays),
                          {**self.inner.meta_static(d),
                           "spec": spec, "d": d})
        return payload, residual, decoded

    def roundtrip_stacked(self, flats, spec, states=None, *, keys=None):
        """Residual add + batched inner encode over the stacked axis.

        Row i is bit-identical to ``roundtrip_flat(flats[i], ...,
        states[i], key=keys[i])`` — residual accumulation is elementwise,
        so stacking commutes with it."""
        c = flats.shape[0]
        states = list(states) if states is not None else [None] * c
        keys = list(keys) if keys is not None else [None] * c
        if any(k is None for k in keys):
            # per-row base loop keeps the None-key (deterministic
            # rounding) semantics of the inner codec
            return super().roundtrip_stacked(flats, spec, states,
                                             keys=keys)
        sts = jnp.stack([jnp.zeros_like(flats[i]) if s is None else s
                         for i, s in enumerate(states)])
        arrays, decoded, residual = self._jit_rt_stacked()(flats, sts,
                                                           jnp.stack(keys))
        payloads = self.inner.stacked_payloads_from_arrays(
            arrays, c, spec, int(flats.shape[1]))
        return payloads, [residual[i] for i in range(c)], decoded

    def encode_stacked(self, flats, spec, states=None, *, keys=None):
        payloads, new_states, _ = self.roundtrip_stacked(
            flats, spec, states, keys=keys)
        return payloads, new_states

    # -- traced API: the residual is the state array ---------------------
    # A host state of None and a traced state of zeros are the same
    # residual by construction (x + 0 == x), so the conversions are
    # lossless in both directions.

    def init_state_traced(self, d: int, host_state=None):
        return (jnp.zeros((d,), jnp.float32) if host_state is None
                else jnp.asarray(host_state, jnp.float32))

    def state_to_host(self, state):
        return state

    def init_states_traced(self, d: int, host_states):
        return jnp.stack([self.init_state_traced(d, s)
                          for s in host_states])

    def states_to_host(self, states, n: int):
        return [states[i] for i in range(n)]

    def roundtrip_traced(self, flat, state, *, key=None):
        adj = flat + state
        dec, _ = self.inner.roundtrip_traced(adj, (), key=key)
        return dec, adj - dec

    def roundtrip_traced_stacked(self, flats, states, *, keys=None):
        adj = flats + states
        dec, _ = self.inner.roundtrip_traced_stacked(adj, (), keys=keys)
        return dec, adj - dec

    def decode(self, payload: Payload):
        return self.inner.decode(payload)

    def encode_flat(self, flat, *, key=None):
        return self.inner.encode_flat(flat, key=key)

    def decode_flat(self, payload):
        return self.inner.decode_flat(payload)

    def bits_per_param(self, d: int) -> float:
        return self.inner.bits_per_param(d)

    def nbytes_static(self, d: int) -> int:
        return self.inner.nbytes_static(d)

    def meta_static(self, d: int):
        return self.inner.meta_static(d)


class DeltaCodec(Codec):
    """Broadcast the delta vs the last round's reconstruction (downlink).

    The server encodes θ_t − ref_{t-1} through the inner codec and both
    ends advance their reference to the *reconstruction* ref_t = ref_{t-1}
    + decode(payload), so a lossy inner codec never lets server and
    clients drift apart.  Round-to-round parameter deltas are orders of
    magnitude smaller than the weights themselves, so the inner
    quantizer's per-block scale (absmax/qmax) — and with it the
    distortion — shrinks accordingly at identical wire bytes.  The first
    transmission (ref = None) carries the full parameters.

    state is the pair (reference flat vector, inner codec state); decode
    requires the receiver's reference, so this codec is only usable
    through the ``roundtrip*`` API (which the engine's downlink uses) —
    a bare ``decode`` raises.  In the async scheduler every version is
    encoded exactly once in order, so a client dispatched at version v
    receives the chain reconstruction ref_v regardless of which version
    it previously held (reliable cumulative-delta multicast).
    """

    stateful = True

    def __init__(self, inner: Codec):
        self.inner = inner
        self.name = "delta+" + inner.name

    def roundtrip_flat(self, flat, spec, state=None, *, key=None):
        ref, inner_state = (None, None) if state is None else state
        base = jnp.zeros_like(flat) if ref is None else ref
        payload, inner_state, dec_delta = self.inner.roundtrip_flat(
            flat - base, spec, inner_state, key=key)
        decoded = base + dec_delta
        return payload, (decoded, inner_state), decoded

    def roundtrip(self, tree, state=None, *, key=None):
        flat, spec = tree_to_flat(tree)
        payload, new_state, decoded = self.roundtrip_flat(flat, spec, state,
                                                          key=key)
        return payload, new_state, flat_to_tree(decoded, spec)

    def encode(self, tree, state=None, *, key=None):
        payload, new_state, _ = self.roundtrip(tree, state, key=key)
        return payload, new_state

    def decode(self, payload: Payload):
        raise NotImplementedError(
            "delta codec reconstruction needs the receiver's reference; "
            "use roundtrip/roundtrip_flat")

    def decode_flat(self, payload: Payload):
        raise NotImplementedError(
            "delta codec reconstruction needs the receiver's reference; "
            "use roundtrip/roundtrip_flat")

    # -- traced API: state = (reference reconstruction, inner state) -----
    # A host reference of None and a traced reference of zeros encode the
    # same first transmission (flat - 0 is the full parameters).

    def init_state_traced(self, d: int, host_state=None):
        ref, inner = (None, None) if host_state is None else host_state
        ref = (jnp.zeros((d,), jnp.float32) if ref is None
               else jnp.asarray(ref, jnp.float32))
        return (ref, self.inner.init_state_traced(d, inner))

    def state_to_host(self, state):
        ref, inner = state
        return (ref, self.inner.state_to_host(inner))

    def init_states_traced(self, d: int, host_states):
        refs, inners = [], []
        for s in host_states:
            ref, inner = self.init_state_traced(d, s)
            refs.append(ref)
            inners.append(inner)
        # inner states are () for every shipped inner codec family except
        # EF, whose residual rows stack
        inner_stacked = (() if (not inners or isinstance(inners[0], tuple))
                         else jnp.stack(inners))
        return (jnp.stack(refs), inner_stacked)

    def states_to_host(self, states, n: int):
        refs, inner = states
        inner_host = self.inner.states_to_host(inner, n)
        return [(refs[i], inner_host[i]) for i in range(n)]

    def roundtrip_traced(self, flat, state, *, key=None):
        ref, inner_state = state
        dec_delta, inner_state = self.inner.roundtrip_traced(
            flat - ref, inner_state, key=key)
        decoded = ref + dec_delta
        return decoded, (decoded, inner_state)

    def roundtrip_traced_stacked(self, flats, states, *, keys=None):
        refs, inner_states = states
        dec_delta, inner_states = self.inner.roundtrip_traced_stacked(
            flats - refs, inner_states, keys=keys)
        decoded = refs + dec_delta
        return decoded, (decoded, inner_states)

    def bits_per_param(self, d: int) -> float:
        return self.inner.bits_per_param(d)

    def nbytes_static(self, d: int) -> int:
        return self.inner.nbytes_static(d)

    def meta_static(self, d: int):
        return self.inner.meta_static(d)
