"""Codec protocol + payload container for federated uplink/downlink traffic.

A ``Codec`` turns a param/delta pytree into a ``Payload`` — a bag of
*actually transmitted* arrays whose ``nbytes`` is measured from the buffer
dtypes (int8 codes count 1 byte, packed int4 nibbles half a byte, ...),
replacing the old f32-only ``tree_param_bytes`` assumption — and back.

Codecs are stateless objects; per-client compression state (the error
feedback residual) is threaded explicitly through ``encode`` so one codec
instance serves every client while residuals stay client-local:

    payload, state = codec.encode(tree, state, key=key)
    tree2 = codec.decode(payload)

``ErrorFeedback`` wraps any lossy codec: the client adds its accumulated
residual before encoding and keeps the new residual (x + e) - decode(...)
locally, so quantization/sparsification error is re-injected instead of
lost — the standard EF trick that restores convergence under biased
compressors (cf. PowerSGD / EF-SGD).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class Payload:
    """What actually crosses the wire: named buffers + static metadata.

    ``meta`` (treedef, shapes, codec params) is O(#leaves) python data —
    negligible next to the O(d) buffers and excluded from the byte count.
    """
    kind: str
    arrays: Dict[str, jnp.ndarray]
    meta: Dict[str, Any]

    @property
    def nbytes(self) -> int:
        return int(sum(a.size * a.dtype.itemsize
                       for a in self.arrays.values()))


@dataclasses.dataclass(frozen=True)
class TreeSpec:
    """Enough structure to rebuild a pytree from a flat f32 vector."""
    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]

    @property
    def size(self) -> int:
        out = 0
        for s in self.shapes:
            n = 1
            for x in s:
                n *= x
            out += n
        return out


def tree_to_flat(tree) -> Tuple[jnp.ndarray, TreeSpec]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    spec = TreeSpec(treedef, tuple(l.shape for l in leaves),
                    tuple(l.dtype for l in leaves))
    flat = jnp.concatenate([l.astype(jnp.float32).reshape(-1)
                            for l in leaves])
    return flat, spec


def flat_to_tree(flat: jnp.ndarray, spec: TreeSpec):
    leaves, off = [], 0
    for shape, dtype in zip(spec.shapes, spec.dtypes):
        n = 1
        for s in shape:
            n *= s
        leaves.append(flat[off:off + n].reshape(shape).astype(dtype))
        off += n
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)


class Codec:
    """Base codec: subclasses implement the flat-vector transform."""

    name = "codec"
    stateful = False

    # -- flat-vector transform (override) -------------------------------
    def encode_flat(self, flat: jnp.ndarray, *, key=None
                    ) -> Tuple[Dict[str, jnp.ndarray], Dict[str, Any]]:
        raise NotImplementedError

    def decode_flat(self, payload: Payload) -> jnp.ndarray:
        raise NotImplementedError

    def bits_per_param(self, d: int) -> float:
        """Analytic uplink cost model (exact for the buffer layout)."""
        raise NotImplementedError

    def _flat_payload(self, flat: jnp.ndarray, spec: "TreeSpec", *,
                      key=None) -> Payload:
        arrays, meta = self.encode_flat(flat, key=key)
        meta["spec"] = spec
        meta["d"] = int(flat.size)
        return Payload(self.name, arrays, meta)

    # -- pytree API -----------------------------------------------------
    def encode(self, tree, state=None, *, key=None
               ) -> Tuple[Payload, Optional[Any]]:
        flat, spec = tree_to_flat(tree)
        return self._flat_payload(flat, spec, key=key), state

    def decode(self, payload: Payload):
        flat = self.decode_flat(payload)[:payload.meta["d"]]
        return flat_to_tree(flat, payload.meta["spec"])

    def roundtrip(self, tree, state=None, *, key=None):
        """encode + what the receiver will decode, in one call.

        Returns (payload, new_state, decoded_tree).  ErrorFeedback
        overrides this to reuse the decode it already computed for the
        residual instead of running a second O(d) decode.
        """
        payload, new_state = self.encode(tree, state, key=key)
        return payload, new_state, self.decode(payload)

    # -- pre-flattened API ----------------------------------------------
    def roundtrip_flat(self, flat: jnp.ndarray, spec: "TreeSpec",
                       state=None, *, key=None):
        """Per-client Payload boundary for pre-flattened uplinks.

        The vectorized engine flattens all C client deltas in ONE batched
        tree op and hands each codec a (d,) f32 row plus the shared
        ``TreeSpec``, skipping C per-client ``tree_to_flat``/
        ``flat_to_tree`` passes.  Returns (payload, new_state,
        decoded_flat) — byte-identical payloads to ``roundtrip``.
        """
        payload = self._flat_payload(flat, spec, key=key)
        return payload, state, self.decode_flat(payload)[:flat.size]

    # -- stacked-client API ---------------------------------------------
    def encode_stacked(self, flats: jnp.ndarray, spec: "TreeSpec",
                       states=None, *, keys=None):
        """Encode all C client rows of a (C, d) stacked flat array.

        Returns (payloads, new_states) — one Payload per client,
        byte-identical to C per-client ``encode``/``roundtrip_flat``
        calls with the same per-client keys.  The base implementation
        loops; batch-shaped codecs (int8/int4) override it to run ONE
        kernel dispatch over the stacked axis (the cohort dispatch path).
        """
        c = flats.shape[0]
        states = list(states) if states is not None else [None] * c
        keys = list(keys) if keys is not None else [None] * c
        payloads = [self._flat_payload(flats[i], spec, key=keys[i])
                    for i in range(c)]
        return payloads, states

    def roundtrip_stacked(self, flats: jnp.ndarray, spec: "TreeSpec",
                          states=None, *, keys=None):
        """``roundtrip_flat`` over the stacked client axis.

        Returns (payloads, new_states, decoded) with decoded shaped
        (C, d).  The base implementation threads per-client state through
        C ``roundtrip_flat`` calls — exact for any codec, including
        stateful wrappers; quantize codecs override with a batched
        single-dispatch path.
        """
        c = flats.shape[0]
        states = list(states) if states is not None else [None] * c
        keys = list(keys) if keys is not None else [None] * c
        payloads, new_states, decs = [], [], []
        for i in range(c):
            p, s, d = self.roundtrip_flat(flats[i], spec, states[i],
                                          key=keys[i])
            payloads.append(p)
            new_states.append(s)
            decs.append(d)
        return payloads, new_states, jnp.stack(decs)


class IdentityCodec(Codec):
    """Raw f32 — the baseline every ratio in the benchmarks is against."""

    name = "identity"

    def encode_flat(self, flat, *, key=None):
        return {"values": flat.astype(jnp.float32)}, {}

    def decode_flat(self, payload):
        return payload.arrays["values"]

    def bits_per_param(self, d: int) -> float:
        return 32.0


class ErrorFeedback(Codec):
    """Residual-accumulating wrapper around a lossy inner codec.

    state is the client-local residual flat vector (starts at zero);
    decode is the inner codec's (the server never sees the residual).
    """

    stateful = True

    def __init__(self, inner: Codec):
        self.inner = inner
        self.name = inner.name + "+ef"

    def _encode_flat_with_decoded(self, flat, spec, state, key):
        if state is not None:
            flat = flat + state
        payload = self.inner._flat_payload(flat, spec, key=key)
        decoded = self.inner.decode_flat(payload)[:flat.size]
        return payload, flat - decoded, decoded

    def _encode_with_decoded(self, tree, state, key):
        flat, spec = tree_to_flat(tree)
        return self._encode_flat_with_decoded(flat, spec, state, key)

    def encode(self, tree, state=None, *, key=None):
        payload, residual, _ = self._encode_with_decoded(tree, state, key)
        return payload, residual

    def roundtrip(self, tree, state=None, *, key=None):
        payload, residual, decoded = self._encode_with_decoded(
            tree, state, key)
        return payload, residual, flat_to_tree(decoded,
                                               payload.meta["spec"])

    def roundtrip_flat(self, flat, spec, state=None, *, key=None):
        payload, residual, decoded = self._encode_flat_with_decoded(
            flat, spec, state, key)
        return payload, residual, decoded

    def roundtrip_stacked(self, flats, spec, states=None, *, keys=None):
        """Residual add + batched inner encode over the stacked axis.

        Row i is bit-identical to ``roundtrip_flat(flats[i], ...,
        states[i], key=keys[i])`` — residual accumulation is elementwise,
        so stacking commutes with it."""
        c = flats.shape[0]
        states = list(states) if states is not None else [None] * c
        adj = jnp.stack([flats[i] if states[i] is None
                         else flats[i] + states[i] for i in range(c)])
        payloads, _, decoded = self.inner.roundtrip_stacked(
            adj, spec, None, keys=keys)
        residual = adj - decoded
        return payloads, [residual[i] for i in range(c)], decoded

    def encode_stacked(self, flats, spec, states=None, *, keys=None):
        payloads, new_states, _ = self.roundtrip_stacked(
            flats, spec, states, keys=keys)
        return payloads, new_states

    def decode(self, payload: Payload):
        return self.inner.decode(payload)

    def encode_flat(self, flat, *, key=None):
        return self.inner.encode_flat(flat, key=key)

    def decode_flat(self, payload):
        return self.inner.decode_flat(payload)

    def bits_per_param(self, d: int) -> float:
        return self.inner.bits_per_param(d)


class DeltaCodec(Codec):
    """Broadcast the delta vs the last round's reconstruction (downlink).

    The server encodes θ_t − ref_{t-1} through the inner codec and both
    ends advance their reference to the *reconstruction* ref_t = ref_{t-1}
    + decode(payload), so a lossy inner codec never lets server and
    clients drift apart.  Round-to-round parameter deltas are orders of
    magnitude smaller than the weights themselves, so the inner
    quantizer's per-block scale (absmax/qmax) — and with it the
    distortion — shrinks accordingly at identical wire bytes.  The first
    transmission (ref = None) carries the full parameters.

    state is the pair (reference flat vector, inner codec state); decode
    requires the receiver's reference, so this codec is only usable
    through the ``roundtrip*`` API (which the engine's downlink uses) —
    a bare ``decode`` raises.  In the async scheduler every version is
    encoded exactly once in order, so a client dispatched at version v
    receives the chain reconstruction ref_v regardless of which version
    it previously held (reliable cumulative-delta multicast).
    """

    stateful = True

    def __init__(self, inner: Codec):
        self.inner = inner
        self.name = "delta+" + inner.name

    def roundtrip_flat(self, flat, spec, state=None, *, key=None):
        ref, inner_state = (None, None) if state is None else state
        base = jnp.zeros_like(flat) if ref is None else ref
        payload, inner_state, dec_delta = self.inner.roundtrip_flat(
            flat - base, spec, inner_state, key=key)
        decoded = base + dec_delta
        return payload, (decoded, inner_state), decoded

    def roundtrip(self, tree, state=None, *, key=None):
        flat, spec = tree_to_flat(tree)
        payload, new_state, decoded = self.roundtrip_flat(flat, spec, state,
                                                          key=key)
        return payload, new_state, flat_to_tree(decoded, spec)

    def encode(self, tree, state=None, *, key=None):
        payload, new_state, _ = self.roundtrip(tree, state, key=key)
        return payload, new_state

    def decode(self, payload: Payload):
        raise NotImplementedError(
            "delta codec reconstruction needs the receiver's reference; "
            "use roundtrip/roundtrip_flat")

    def decode_flat(self, payload: Payload):
        raise NotImplementedError(
            "delta codec reconstruction needs the receiver's reference; "
            "use roundtrip/roundtrip_flat")

    def bits_per_param(self, d: int) -> float:
        return self.inner.bits_per_param(d)
