"""Codec protocol + payload container for federated uplink/downlink traffic.

A ``Codec`` turns a param/delta pytree into a ``Payload`` — a bag of
*actually transmitted* arrays whose ``nbytes`` is measured from the buffer
dtypes (int8 codes count 1 byte, packed int4 nibbles half a byte, ...),
replacing the old f32-only ``tree_param_bytes`` assumption — and back.

Codecs are stateless objects; per-client compression state (the error
feedback residual) is threaded explicitly through ``encode`` so one codec
instance serves every client while residuals stay client-local:

    payload, state = codec.encode(tree, state, key=key)
    tree2 = codec.decode(payload)

``ErrorFeedback`` wraps any lossy codec: the client adds its accumulated
residual before encoding and keeps the new residual (x + e) - decode(...)
locally, so quantization/sparsification error is re-injected instead of
lost — the standard EF trick that restores convergence under biased
compressors (cf. PowerSGD / EF-SGD).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class Payload:
    """What actually crosses the wire: named buffers + static metadata.

    ``meta`` (treedef, shapes, codec params) is O(#leaves) python data —
    negligible next to the O(d) buffers and excluded from the byte count.
    """
    kind: str
    arrays: Dict[str, jnp.ndarray]
    meta: Dict[str, Any]

    @property
    def nbytes(self) -> int:
        return int(sum(a.size * a.dtype.itemsize
                       for a in self.arrays.values()))


@dataclasses.dataclass(frozen=True)
class TreeSpec:
    """Enough structure to rebuild a pytree from a flat f32 vector."""
    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]

    @property
    def size(self) -> int:
        out = 0
        for s in self.shapes:
            n = 1
            for x in s:
                n *= x
            out += n
        return out


def tree_to_flat(tree) -> Tuple[jnp.ndarray, TreeSpec]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    spec = TreeSpec(treedef, tuple(l.shape for l in leaves),
                    tuple(l.dtype for l in leaves))
    flat = jnp.concatenate([l.astype(jnp.float32).reshape(-1)
                            for l in leaves])
    return flat, spec


def flat_to_tree(flat: jnp.ndarray, spec: TreeSpec):
    leaves, off = [], 0
    for shape, dtype in zip(spec.shapes, spec.dtypes):
        n = 1
        for s in shape:
            n *= s
        leaves.append(flat[off:off + n].reshape(shape).astype(dtype))
        off += n
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)


class Codec:
    """Base codec: subclasses implement the flat-vector transform."""

    name = "codec"
    stateful = False

    # -- flat-vector transform (override) -------------------------------
    def encode_flat(self, flat: jnp.ndarray, *, key=None
                    ) -> Tuple[Dict[str, jnp.ndarray], Dict[str, Any]]:
        raise NotImplementedError

    def decode_flat(self, payload: Payload) -> jnp.ndarray:
        raise NotImplementedError

    def bits_per_param(self, d: int) -> float:
        """Analytic uplink cost model (exact for the buffer layout)."""
        raise NotImplementedError

    def _flat_payload(self, flat: jnp.ndarray, spec: "TreeSpec", *,
                      key=None) -> Payload:
        arrays, meta = self.encode_flat(flat, key=key)
        meta["spec"] = spec
        meta["d"] = int(flat.size)
        return Payload(self.name, arrays, meta)

    # -- pytree API -----------------------------------------------------
    def encode(self, tree, state=None, *, key=None
               ) -> Tuple[Payload, Optional[Any]]:
        flat, spec = tree_to_flat(tree)
        return self._flat_payload(flat, spec, key=key), state

    def decode(self, payload: Payload):
        flat = self.decode_flat(payload)[:payload.meta["d"]]
        return flat_to_tree(flat, payload.meta["spec"])

    def roundtrip(self, tree, state=None, *, key=None):
        """encode + what the receiver will decode, in one call.

        Returns (payload, new_state, decoded_tree).  ErrorFeedback
        overrides this to reuse the decode it already computed for the
        residual instead of running a second O(d) decode.
        """
        payload, new_state = self.encode(tree, state, key=key)
        return payload, new_state, self.decode(payload)

    # -- pre-flattened API ----------------------------------------------
    def roundtrip_flat(self, flat: jnp.ndarray, spec: "TreeSpec",
                       state=None, *, key=None):
        """Per-client Payload boundary for pre-flattened uplinks.

        The vectorized engine flattens all C client deltas in ONE batched
        tree op and hands each codec a (d,) f32 row plus the shared
        ``TreeSpec``, skipping C per-client ``tree_to_flat``/
        ``flat_to_tree`` passes.  Returns (payload, new_state,
        decoded_flat) — byte-identical payloads to ``roundtrip``.
        """
        payload = self._flat_payload(flat, spec, key=key)
        return payload, state, self.decode_flat(payload)[:flat.size]


class IdentityCodec(Codec):
    """Raw f32 — the baseline every ratio in the benchmarks is against."""

    name = "identity"

    def encode_flat(self, flat, *, key=None):
        return {"values": flat.astype(jnp.float32)}, {}

    def decode_flat(self, payload):
        return payload.arrays["values"]

    def bits_per_param(self, d: int) -> float:
        return 32.0


class ErrorFeedback(Codec):
    """Residual-accumulating wrapper around a lossy inner codec.

    state is the client-local residual flat vector (starts at zero);
    decode is the inner codec's (the server never sees the residual).
    """

    stateful = True

    def __init__(self, inner: Codec):
        self.inner = inner
        self.name = inner.name + "+ef"

    def _encode_flat_with_decoded(self, flat, spec, state, key):
        if state is not None:
            flat = flat + state
        payload = self.inner._flat_payload(flat, spec, key=key)
        decoded = self.inner.decode_flat(payload)[:flat.size]
        return payload, flat - decoded, decoded

    def _encode_with_decoded(self, tree, state, key):
        flat, spec = tree_to_flat(tree)
        return self._encode_flat_with_decoded(flat, spec, state, key)

    def encode(self, tree, state=None, *, key=None):
        payload, residual, _ = self._encode_with_decoded(tree, state, key)
        return payload, residual

    def roundtrip(self, tree, state=None, *, key=None):
        payload, residual, decoded = self._encode_with_decoded(
            tree, state, key)
        return payload, residual, flat_to_tree(decoded,
                                               payload.meta["spec"])

    def roundtrip_flat(self, flat, spec, state=None, *, key=None):
        payload, residual, decoded = self._encode_flat_with_decoded(
            flat, spec, state, key)
        return payload, residual, decoded

    def decode(self, payload: Payload):
        return self.inner.decode(payload)

    def encode_flat(self, flat, *, key=None):
        return self.inner.encode_flat(flat, key=key)

    def decode_flat(self, payload):
        return self.inner.decode_flat(payload)

    def bits_per_param(self, d: int) -> float:
        return self.inner.bits_per_param(d)
