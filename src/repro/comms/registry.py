"""Codec registry + spec-string parser.

Spec grammar:  [delta+]<name>[:<arg>][+ef]

    identity            raw f32 (32 bits/param)
    int8                blockwise stochastic int8 (~8.03 bits/param)
    int4                nibble-packed stochastic int4 (~4.03 bits/param)
    topk:<frac>         magnitude top-k, frac of params kept (64*frac)
    lowrank:<rank>      PowerSGD-style rank-r sketch (~64r/sqrt(d))
    ...+ef              wrap in client-local error feedback
    delta+...           transmit the delta vs the last round's
                        reconstruction (downlink broadcast codec); same
                        bits/param as the inner codec, far lower
                        distortion from round 2 on

Examples: "int8", "int4+ef", "topk:0.05+ef", "lowrank:8", "delta+int8".
"""
from __future__ import annotations

from typing import Callable, Dict

from repro.comms.codec import Codec, DeltaCodec, ErrorFeedback, IdentityCodec
from repro.comms.lowrank import LowRankCodec
from repro.comms.quantize import QuantizeCodec
from repro.comms.sparsify import TopKCodec

_FACTORIES: Dict[str, Callable[[str], Codec]] = {}


def register(name: str):
    def deco(factory):
        _FACTORIES[name] = factory
        return factory
    return deco


@register("identity")
def _identity(arg: str) -> Codec:
    return IdentityCodec()


@register("int8")
def _int8(arg: str) -> Codec:
    return QuantizeCodec(bits=8, stochastic=(arg != "det"))


@register("int4")
def _int4(arg: str) -> Codec:
    return QuantizeCodec(bits=4, stochastic=(arg != "det"))


@register("topk")
def _topk(arg: str) -> Codec:
    return TopKCodec(frac=float(arg or 0.05))


@register("lowrank")
def _lowrank(arg: str) -> Codec:
    return LowRankCodec(rank=int(arg or 4))


def available() -> tuple:
    return tuple(sorted(_FACTORIES))


def make_codec(spec: str) -> Codec:
    """'topk:0.05+ef' -> ErrorFeedback(TopKCodec(0.05))."""
    spec = (spec or "identity").strip()
    # delta composes OUTSIDE the rest of the spec ("delta+int8+ef" ->
    # DeltaCodec(ErrorFeedback(int8))): the inner codec sees the delta
    # stream, reference tracking stays in the wrapper
    if spec == "delta" or spec.startswith("delta+"):
        return DeltaCodec(make_codec(spec[len("delta+"):] or "identity"))
    wrap_ef = spec.endswith("+ef")
    if wrap_ef:
        spec = spec[:-3]
    name, _, arg = spec.partition(":")
    if name not in _FACTORIES:
        raise ValueError(
            f"unknown codec {name!r}; available: {available()}")
    codec = _FACTORIES[name](arg)
    if wrap_ef:
        if isinstance(codec, IdentityCodec):
            raise ValueError("identity codec is lossless; +ef is a no-op "
                             "and almost certainly a config mistake")
        codec = ErrorFeedback(codec)
    return codec
