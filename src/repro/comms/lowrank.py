"""Low-rank sketch codec (PowerSGD-style randomized range finder).

The flat vector is reshaped to a near-square (a, b) matrix X and
approximated as Q @ B with Q = orth(X @ (X^T X)^p Ω) an (a, r) orthonormal
basis and B = Q^T X the (r, b) projection — wire cost r*(a+b) f32 words
instead of a*b, i.e. ~2r/sqrt(d) of identity.  Rank-r truncation is
biased, so "lowrank:r+ef" is the recommended spelling (exactly PowerSGD's
error-feedback construction).

The Gram/projection matmuls are the same streaming (tall, skinny)
contraction the gram Pallas kernel covers; at repro scale XLA's dot is
used directly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.comms.codec import Codec


def _matrix_shape(d: int):
    a = 1
    while a * a < d:
        a *= 2
    b = -(-d // a)
    return a, b


class LowRankCodec(Codec):
    def __init__(self, rank: int = 4, power_iters: int = 1):
        if rank < 1:
            raise ValueError(f"lowrank rank must be >= 1, got {rank}")
        self.rank = rank
        self.power_iters = power_iters
        self.name = f"lowrank:{rank}"

    def encode_flat(self, flat, *, key=None):
        d = flat.size
        a, b = _matrix_shape(d)
        x = jnp.pad(flat, (0, a * b - d)).reshape(a, b)
        key = key if key is not None else jax.random.PRNGKey(0)
        omega = jax.random.normal(key, (b, self.rank), jnp.float32)
        p = x @ omega                              # (a, r) range sample
        for _ in range(self.power_iters):
            p = x @ (x.T @ p)
        q, _ = jnp.linalg.qr(p)                    # (a, r) orthonormal
        bmat = q.T @ x                             # (r, b)
        return ({"q": q.astype(jnp.float32), "b": bmat.astype(jnp.float32)},
                {"a": a, "b_cols": b})

    def decode_flat(self, payload):
        x = payload.arrays["q"] @ payload.arrays["b"]
        return x.reshape(-1)

    def bits_per_param(self, d: int) -> float:
        a, b = _matrix_shape(d)
        return 32.0 * self.rank * (a + b) / d

    def nbytes_static(self, d: int) -> int:
        a, b = _matrix_shape(d)
        return 4 * self.rank * (a + b)

    def meta_static(self, d: int):
        a, b = _matrix_shape(d)
        return {"a": a, "b_cols": b}
