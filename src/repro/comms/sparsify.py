"""Magnitude top-k sparsification codec.

Transmits the k = frac*d largest-magnitude entries as (int32 index, f32
value) pairs — 64 bits per kept param, so frac=0.05 is ~10% of identity.
Top-k is biased; pair it with error feedback ("topk:0.05+ef") so dropped
coordinates eventually ship once their residual accumulates.

Selection uses the TPU-friendly threshold-refinement path (bisection on
Pallas magnitude-count passes + a dense mask pass — no O(d log d) sort);
indices then fall out of a stable argsort of the boolean mask.  The jnp
reference path is ``jax.lax.top_k``; tests pin both to the same support.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.comms.codec import Codec
from repro.comms.quantize import _to_blocks
from repro.kernels import ops


def topk_support(flat: jnp.ndarray, k: int, use_pallas: bool = True):
    """Indices (sorted ascending) + values of the k largest |entries|."""
    d = flat.size
    k = max(1, min(int(k), d))
    if not use_pallas:
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        idx = jnp.sort(idx)
        return idx.astype(jnp.int32), flat[idx]
    lo, hi = ops.topk_threshold(_to_blocks(flat), k, use_pallas=True)
    absx = jnp.abs(flat)
    # |x| >= hi are definite top-k members (< k of them unless every
    # entry ties at the max); entries in [lo, hi) are boundary ties that
    # fill the remaining slots, broken by index.  A stable argsort on
    # the category puts definite first, then ties, each in index order.
    cat = jnp.where(absx >= hi, 0, jnp.where(absx >= lo, 1, 2))
    idx = jnp.sort(jnp.argsort(cat, stable=True)[:k])
    return idx.astype(jnp.int32), flat[idx]


class TopKCodec(Codec):
    def __init__(self, frac: float = 0.05, use_pallas: bool = True):
        if not 0.0 < frac <= 1.0:
            raise ValueError(f"topk frac must be in (0, 1], got {frac}")
        self.frac = frac
        self.use_pallas = use_pallas
        self.name = f"topk:{frac:g}"

    def encode_flat(self, flat, *, key=None):
        k = max(1, int(round(self.frac * flat.size)))
        idx, vals = topk_support(flat, k, use_pallas=self.use_pallas)
        return ({"indices": idx, "values": vals.astype(jnp.float32)},
                {"k": k})

    def decode_flat(self, payload):
        d = payload.meta["d"]
        out = jnp.zeros((d,), jnp.float32)
        return out.at[payload.arrays["indices"]].set(
            payload.arrays["values"])

    def bits_per_param(self, d: int) -> float:
        return 64.0 * self.frac

    def nbytes_static(self, d: int) -> int:
        # k (int32 index, f32 value) pairs; k depends on d alone
        return 8 * max(1, int(round(self.frac * d)))

    def meta_static(self, d: int):
        return {"k": max(1, int(round(self.frac * d)))}
