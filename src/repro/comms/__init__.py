"""Comms codec subsystem: measured-byte uplink/downlink compression.

Turns the engine's raw f32 pytree traffic into encoded ``Payload``s with
exact wire-byte accounting, optional per-client error feedback, and
Pallas-kernel hot paths (see README.md in this package).

    from repro.comms import make_codec
    codec = make_codec("int8+ef")
    payload, state = codec.encode(delta_tree, state, key=key)
    delta2 = codec.decode(payload)          # payload.nbytes on the wire

Analytic per-round models live in repro.core.comms; this package is the
measured counterpart wired through repro.fed.engine.

Every codec also implements the traced contract used by the fused
multi-round engine (``roundtrip_traced*`` with explicit array state,
``nbytes_static`` exact byte accounting, ``Payload.nbytes_entropy``
ideal-coder estimates) — see README.md and repro.fed.engine.
"""
from repro.comms.codec import (Codec, DeltaCodec, ErrorFeedback,
                               IdentityCodec, Payload, flat_to_tree,
                               tree_to_flat)
from repro.comms.lowrank import LowRankCodec
from repro.comms.quantize import QuantizeCodec
from repro.comms.registry import available, make_codec
from repro.comms.sparsify import TopKCodec

__all__ = [
    "Codec", "DeltaCodec", "ErrorFeedback", "IdentityCodec", "Payload",
    "QuantizeCodec", "TopKCodec", "LowRankCodec",
    "available", "make_codec", "tree_to_flat", "flat_to_tree",
]
