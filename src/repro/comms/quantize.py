"""Blockwise int8 / int4 stochastic quantization codecs.

The flat vector is padded to (R, BLOCK) groups; each group carries one f32
scale.  int8 transmits the codes raw (1 byte/param); int4 packs two codes
per byte, so the wire cost is 0.5 byte/param + 4/BLOCK bytes of scales.
Stochastic rounding (uniform uint32 offsets) keeps the quantizer unbiased,
which is what lets FedAvg of C decoded uploads concentrate around the true
mean; pass ``stochastic=False`` for deterministic round-to-nearest.

Hot paths run through the Pallas kernels in repro/kernels/quantize.py
(interpret-mode on CPU, native on TPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.comms.codec import Codec, Payload
from repro.kernels import ops
from repro.kernels.quantize import BLOCK, _DET_BITS


def _to_blocks(flat: jnp.ndarray):
    d = flat.size
    rows = -(-d // BLOCK)
    pad = rows * BLOCK - d
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(rows, BLOCK)


def pack_int4(codes: jnp.ndarray) -> jnp.ndarray:
    """int8 codes in [-7, 7] -> uint8, two nibbles per byte."""
    u = (codes.astype(jnp.int32) + 8).astype(jnp.uint8)      # [1, 15]
    lo, hi = u[..., 0::2], u[..., 1::2]
    return (lo << 4) | hi


def unpack_int4(packed: jnp.ndarray) -> jnp.ndarray:
    lo = (packed >> 4).astype(jnp.int32) - 8
    hi = (packed & 0xF).astype(jnp.int32) - 8
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*packed.shape[:-1], -1).astype(jnp.int8)


class QuantizeCodec(Codec):
    """bits=8 -> raw int8 codes; bits=4 -> nibble-packed uint8 codes."""

    def __init__(self, bits: int = 8, stochastic: bool = True,
                 use_pallas: bool = True):
        if bits not in (4, 8):
            raise ValueError(f"quantize bits must be 4 or 8, got {bits}")
        self.bits = bits
        self.qmax = 7 if bits == 4 else 127
        self.stochastic = stochastic
        self.use_pallas = use_pallas
        self.name = f"int{bits}"

    def encode_flat(self, flat, *, key=None):
        x2 = _to_blocks(flat)
        if self.stochastic and key is not None:
            rbits = jax.random.bits(key, x2.shape, jnp.uint32)
        else:
            rbits = jnp.full(x2.shape, _DET_BITS, jnp.uint32)
        codes, scales = ops.quantize(x2, rbits, self.qmax,
                                     use_pallas=self.use_pallas)
        if self.bits == 4:
            codes = pack_int4(codes)
        return {"codes": codes, "scales": scales}, {"bits": self.bits}

    def decode_flat(self, payload):
        codes = payload.arrays["codes"]
        if payload.meta["bits"] == 4:
            codes = unpack_int4(codes)
        x2 = ops.dequantize(codes, payload.arrays["scales"],
                            use_pallas=self.use_pallas)
        return x2.reshape(-1)

    def bits_per_param(self, d: int) -> float:
        return self.bits + 32.0 / BLOCK

    def nbytes_static(self, d: int) -> int:
        # padded (rows, BLOCK) codes (int8: 1 byte, int4: packed nibbles)
        # + one f32 scale per row — exactly the measured Payload layout
        rows = -(-d // BLOCK)
        code_bytes = rows * (BLOCK if self.bits == 8 else BLOCK // 2)
        return code_bytes + rows * 4

    def meta_static(self, d: int):
        return {"bits": self.bits}

    # -- stacked-client batched path ------------------------------------
    def _quantize_stacked(self, flats, keys):
        """(C, d) -> one kernel dispatch over the concatenated blocks.

        Each client's blocks are quantized row-independently, so
        concatenating the per-client (rows, BLOCK) groups along the row
        axis and running ONE quantize kernel yields codes/scales
        bit-identical to C per-client calls (the per-client random bits
        still come from that client's key)."""
        c, d = flats.shape
        rows = -(-d // BLOCK)
        pad = rows * BLOCK - d
        x = jnp.pad(flats, ((0, 0), (0, pad))) if pad else flats
        x = x.reshape(c * rows, BLOCK)
        det = jnp.full((rows, BLOCK), _DET_BITS, jnp.uint32)
        if self.stochastic and keys is not None:
            # per-row None keys fall back to round-to-nearest for that
            # client only, matching C per-client encode calls
            rbits = jnp.concatenate(
                [det if k is None else
                 jax.random.bits(k, (rows, BLOCK), jnp.uint32)
                 for k in keys])
        else:
            rbits = jnp.tile(det, (c, 1))
        codes, scales = ops.quantize(x, rbits, self.qmax,
                                     use_pallas=self.use_pallas)
        return codes, scales, rows

    def _stacked_payloads(self, codes, scales, rows, c, spec, d):
        payloads = []
        for i in range(c):
            ci = codes[i * rows:(i + 1) * rows]
            if self.bits == 4:
                ci = pack_int4(ci)
            payloads.append(Payload(
                self.name,
                {"codes": ci, "scales": scales[i * rows:(i + 1) * rows]},
                {"bits": self.bits, "spec": spec, "d": d}))
        return payloads

    def encode_stacked(self, flats, spec, states=None, *, keys=None):
        c, d = flats.shape
        codes, scales, rows = self._quantize_stacked(flats, keys)
        payloads = self._stacked_payloads(codes, scales, rows, c, spec, d)
        return payloads, list(states) if states is not None else [None] * c

    def roundtrip_stacked(self, flats, spec, states=None, *, keys=None):
        c, d = flats.shape
        codes, scales, rows = self._quantize_stacked(flats, keys)
        payloads = self._stacked_payloads(codes, scales, rows, c, spec, d)
        decoded = ops.dequantize(codes, scales, use_pallas=self.use_pallas)
        decoded = decoded.reshape(c, rows * BLOCK)[:, :d]
        return (payloads,
                list(states) if states is not None else [None] * c,
                decoded)

    # -- traced in-graph path -------------------------------------------
    def encode_decode_traced_stacked(self, flats, *, keys=None):
        """Same batched quantize/dequantize as ``roundtrip_stacked`` with
        codes/scales staged in-graph — ONE kernel dispatch over all C
        clients' blocks, bit-identical rows to per-client
        ``roundtrip_traced`` calls — and the wire buffers (int4 packed)
        returned alongside the decode, in the concatenated-row layout
        ``stacked_payloads_from_arrays`` slices.  ``keys`` is a (C, 2)
        key array (stacked callers always supply per-client keys).  The
        wire boundary is marked with (best-effort) optimization barriers
        — see ``Codec.roundtrip_traced`` for what they do and do not
        guarantee."""
        flats = jax.lax.optimization_barrier(flats)
        c, d = flats.shape
        rows = -(-d // BLOCK)
        pad = rows * BLOCK - d
        x = jnp.pad(flats, ((0, 0), (0, pad))) if pad else flats
        x = x.reshape(c * rows, BLOCK)
        if self.stochastic and keys is not None:
            rbits = jax.vmap(
                lambda k: jax.random.bits(k, (rows, BLOCK), jnp.uint32)
            )(keys).reshape(c * rows, BLOCK)
        else:
            rbits = jnp.tile(jnp.full((rows, BLOCK), _DET_BITS,
                                      jnp.uint32), (c, 1))
        codes, scales = ops.quantize(x, rbits, self.qmax,
                                     use_pallas=self.use_pallas)
        decoded = ops.dequantize(codes, scales, use_pallas=self.use_pallas)
        decoded = jax.lax.optimization_barrier(
            decoded.reshape(c, rows * BLOCK)[:, :d])
        wire = pack_int4(codes) if self.bits == 4 else codes
        return {"codes": wire, "scales": scales}, decoded

    def roundtrip_traced_stacked(self, flats, states=(), *, keys=None):
        """Decode-only view of ``encode_decode_traced_stacked`` (the
        unused wire buffers are dead code the compiler drops)."""
        _, decoded = self.encode_decode_traced_stacked(flats, keys=keys)
        return decoded, states

    def stacked_payloads_from_arrays(self, arrays, c, spec, d):
        """Slice the concatenated-row codes/scales into per-client
        Payloads — identical layout (and bytes) to per-client encodes."""
        rows = -(-d // BLOCK)
        meta = self.meta_static(d)
        return [Payload(
            self.name,
            {"codes": arrays["codes"][i * rows:(i + 1) * rows],
             "scales": arrays["scales"][i * rows:(i + 1) * rows]},
            {**meta, "spec": spec, "d": d}) for i in range(c)]
