"""Chrome/Perfetto trace-event rendering of the simulated schedule.

``TraceBuilder`` accumulates trace events in the Chrome trace-event JSON
format (the ``{"traceEvents": [...]}`` object form) that
https://ui.perfetto.dev opens directly.  Two processes:

  pid 1 "simulated schedule"  the scheduler's simulated clock.  Thread 0
        is the server (round/barrier spans, aggregation instants); thread
        c+1 is client c, whose per-round work renders as consecutive
        download / compute / upload spans (durations from the same
        ``core.comms`` time-from-bytes models the policies use, so span
        sums reproduce the reported simulated wall-clock exactly).
        Deadline drops are instants on the dropped client's track;
        fedbuff uploads connect to the aggregation that consumed them via
        flow arrows, and the event-queue depth renders as a counter
        track.
  pid 2 "host wall-clock"     real time: one span per jitted-program
        entry recorded by ``repro.obs.jitwatch``, with compile-triggering
        calls flagged (``args.compiled``) — compile vs execute cost is
        visible per program.

All simulated timestamps are seconds and render as microseconds (the
trace-event unit); host spans are offset to start at t=0 of their own
process so the two timelines don't visually interleave.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence, Tuple

SIM_PID = 1
HOST_PID = 2
SERVER_TID = 0

SIM_PROCESS_NAME = "simulated schedule"
HOST_PROCESS_NAME = "host wall-clock"


def _us(seconds: float) -> float:
    return float(seconds) * 1e6


class TraceBuilder:
    """Accumulates trace events; ``to_dict()``/``write()`` export them."""

    def __init__(self) -> None:
        self.events: List[dict] = []
        self._flow_id = 0
        self._named: set = set()

    # ------------------------------------------------------- metadata
    def _thread(self, pid: int, tid: int, name: str) -> None:
        if (pid, tid) in self._named:
            return
        self._named.add((pid, tid))
        self.events.append({"ph": "M", "pid": pid, "tid": tid, "ts": 0,
                            "name": "thread_name", "args": {"name": name}})

    # ------------------------------------------------------- simulated
    def client_span(self, client: int, t0: float,
                    segments: Sequence[Tuple[str, float]], *,
                    round_idx: Optional[int] = None,
                    extra: Optional[dict] = None) -> float:
        """Consecutive phase spans on client ``client``'s track starting
        at simulated ``t0``; returns the end time."""
        tid = client + 1
        self._thread(SIM_PID, tid, f"client {client}")
        t = t0
        for label, dur in segments:
            args = {"client": client}
            if round_idx is not None:
                args["round"] = int(round_idx)
            if extra:
                args.update(extra)
            self.events.append({"ph": "X", "pid": SIM_PID, "tid": tid,
                                "cat": "client", "name": label,
                                "ts": _us(t), "dur": _us(dur),
                                "args": args})
            t += dur
        return t

    def server_span(self, name: str, t0: float, dur: float,
                    args: Optional[dict] = None) -> None:
        self._thread(SIM_PID, SERVER_TID, "server")
        self.events.append({"ph": "X", "pid": SIM_PID, "tid": SERVER_TID,
                            "cat": "server", "name": name, "ts": _us(t0),
                            "dur": _us(dur), "args": args or {}})

    def instant(self, name: str, t: float, *, client: Optional[int] = None,
                args: Optional[dict] = None) -> None:
        tid = SERVER_TID if client is None else client + 1
        tname = "server" if client is None else f"client {client}"
        self._thread(SIM_PID, tid, tname)
        self.events.append({"ph": "i", "pid": SIM_PID, "tid": tid,
                            "cat": "server" if client is None else "client",
                            "name": name, "ts": _us(t), "s": "t",
                            "args": args or {}})

    def flow_start(self, name: str, t: float, *, client: int,
                   args: Optional[dict] = None) -> int:
        """Open a flow arrow at simulated ``t`` on a client track; the
        returned id closes it via ``flow_end``."""
        self._flow_id += 1
        self._thread(SIM_PID, client + 1, f"client {client}")
        self.events.append({"ph": "s", "pid": SIM_PID, "tid": client + 1,
                            "cat": "flow", "name": name, "ts": _us(t),
                            "id": self._flow_id, "args": args or {}})
        return self._flow_id

    def flow_end(self, name: str, t: float, flow_id: int,
                 args: Optional[dict] = None) -> None:
        self._thread(SIM_PID, SERVER_TID, "server")
        self.events.append({"ph": "f", "bp": "e", "pid": SIM_PID,
                            "tid": SERVER_TID, "cat": "flow", "name": name,
                            "ts": _us(t), "id": flow_id,
                            "args": args or {}})

    def counter(self, name: str, t: float, values: Dict[str, float]) -> None:
        self.events.append({"ph": "C", "pid": SIM_PID, "tid": SERVER_TID,
                            "cat": "counter", "name": name, "ts": _us(t),
                            "args": {k: float(v) for k, v in values.items()}})

    # ------------------------------------------------------- host time
    def add_host_spans(self, spans, t_base: Optional[float] = None) -> None:
        """Render ``jitwatch`` spans (perf_counter seconds) on the host
        process, offset so the first span starts at 0."""
        if not spans:
            return
        if t_base is None:
            t_base = min(s.t0 for s in spans)
        self._thread(HOST_PID, 0, "jit entry")
        for s in spans:
            self.events.append({
                "ph": "X", "pid": HOST_PID, "tid": 0, "cat": "host",
                "name": s.name, "ts": _us(s.t0 - t_base),
                "dur": _us(s.dur),
                "args": {"compiled": bool(s.compiled)}})

    # ------------------------------------------------------- export
    def to_dict(self) -> dict:
        meta = []
        for pid, pname in ((SIM_PID, SIM_PROCESS_NAME),
                           (HOST_PID, HOST_PROCESS_NAME)):
            if any(e["pid"] == pid for e in self.events):
                meta.append({"ph": "M", "pid": pid, "tid": 0, "ts": 0,
                             "name": "process_name",
                             "args": {"name": pname}})
        return {"traceEvents": meta + list(self.events),
                "displayTimeUnit": "ms"}

    def write(self, path: str) -> dict:
        d = self.to_dict()
        validate_trace(d)
        with open(path, "w") as f:
            json.dump(d, f, indent=1)
        return d


# ---------------------------------------------------------- validation
_REQUIRED = {"ph", "pid", "tid", "name"}
_KNOWN_PH = {"X", "B", "E", "i", "I", "M", "C", "s", "t", "f"}


def validate_trace(trace: dict) -> None:
    """Raise ValueError unless ``trace`` is well-formed Chrome
    trace-event JSON (object form).  Checks the shape constraints the
    Perfetto importer relies on; tests call this, and ``write`` always
    validates before touching disk."""
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        raise ValueError("trace must be an object with 'traceEvents'")
    events = trace["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be a list")
    open_flows = set()
    for i, e in enumerate(events):
        missing = _REQUIRED - set(e)
        if missing:
            raise ValueError(f"event {i} missing keys {sorted(missing)}")
        if e["ph"] not in _KNOWN_PH:
            raise ValueError(f"event {i}: unknown phase {e['ph']!r}")
        if e["ph"] != "M":
            if "ts" not in e:
                raise ValueError(f"event {i}: non-metadata event lacks ts")
            if not isinstance(e["ts"], (int, float)) or e["ts"] < 0:
                raise ValueError(f"event {i}: bad ts {e['ts']!r}")
        if e["ph"] == "X":
            if "dur" not in e or e["dur"] < 0:
                raise ValueError(f"event {i}: X event needs dur >= 0")
        if e["ph"] == "s":
            open_flows.add(e.get("id"))
        if e["ph"] == "f" and e.get("id") not in open_flows:
            raise ValueError(f"event {i}: flow end without start "
                             f"(id {e.get('id')!r})")


def span_seconds_by_track(trace: dict) -> Dict[Tuple[int, int], float]:
    """Sum of X-span durations (in seconds) per (pid, tid) — what the
    tests reconcile against the policies' reported simulated times."""
    out: Dict[Tuple[int, int], float] = {}
    for e in trace["traceEvents"]:
        if e["ph"] == "X":
            key = (e["pid"], e["tid"])
            out[key] = out.get(key, 0.0) + e["dur"] / 1e6
    return out
