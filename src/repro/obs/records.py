"""Versioned metric records and the single round-summary constructor.

Every telemetry datum in the repo is one of three typed records:

  counter  a cumulative, monotonically accumulated quantity (wire bytes,
           jit dispatches) — sinks may diff consecutive values
  gauge    an instantaneous scalar (λ disagreement, param drift, KL,
           simulated round duration)
  series   a small vector sampled once per round (per-objective rewards,
           mean λ, per-client upload bytes)

Records carry ``schema=SCHEMA_VERSION`` so downstream consumers (the CI
bench report, offline notebooks) can reject files written under a
different layout instead of misparsing them.  Bump the version whenever
a record field or a round-summary key changes meaning.

This module is also the ONE place a federated round summary dict is
built: ``round_summary`` is shared by ``FederatedTrainer.run_round`` and
``run_rounds_fused`` (they used to hand-build near-identical dicts), and
``annotate_schedule`` / ``fedbuff_summary`` own the scheduler policies'
additions — so the summary schema cannot drift between producers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

SCHEMA_VERSION = 1

KINDS = ("counter", "gauge", "series")


def _plain(value):
    """Numpy/JAX scalars and arrays -> JSON-able python values."""
    if isinstance(value, (list, tuple)):
        return [_plain(v) for v in value]
    arr = np.asarray(value)
    if arr.ndim == 0:
        return arr.item()
    return arr.tolist()


@dataclasses.dataclass(frozen=True)
class MetricRecord:
    """One typed telemetry datum."""
    kind: str                               # counter | gauge | series
    name: str                               # e.g. "round/rewards"
    value: Any                              # scalar or (for series) list
    round: Optional[int] = None             # server round / version index
    labels: Tuple[Tuple[str, str], ...] = ()
    schema: int = SCHEMA_VERSION

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown record kind {self.kind!r}; "
                             f"expected one of {KINDS}")

    def to_json(self) -> dict:
        d = {"schema": self.schema, "kind": self.kind, "name": self.name,
             "value": _plain(self.value)}
        if self.round is not None:
            d["round"] = int(self.round)
        if self.labels:
            d["labels"] = dict(self.labels)
        return d


def counter(name: str, value, round: Optional[int] = None,
            **labels) -> MetricRecord:
    return MetricRecord("counter", name, _plain(value), round,
                        tuple(sorted((k, str(v)) for k, v in labels.items())))


def gauge(name: str, value, round: Optional[int] = None,
          **labels) -> MetricRecord:
    return MetricRecord("gauge", name, _plain(value), round,
                        tuple(sorted((k, str(v)) for k, v in labels.items())))


def series(name: str, value, round: Optional[int] = None,
           **labels) -> MetricRecord:
    return MetricRecord("series", name, _plain(value), round,
                        tuple(sorted((k, str(v)) for k, v in labels.items())))


# ------------------------------------------------- round-summary builders
def round_summary(*, stats: Dict[str, Any], comm_bytes: int, up_bytes: int,
                  down_bytes: int, participants: Sequence[int],
                  dispatches: float, up_nbytes: Sequence[int],
                  down_nbytes: int, local_steps: Sequence[int],
                  cohorts: int, fused: Optional[int] = None) -> dict:
    """The engine's per-round summary dict — the ONLY constructor.

    ``stats`` holds the device-computed statistics after the round's one
    host transfer (keys: rewards, lam_mean, lam_disagreement,
    param_drift, kl, per_client_lam, rewards_per_client).  Both the
    per-round and the fused executors call this with their own slices;
    ``tests/test_obs.py`` pins the output bit-identical to the legacy
    hand-built dicts.
    """
    summary = {
        "rewards": stats["rewards"],
        "lam_mean": stats["lam_mean"],
        "lam_disagreement": float(stats["lam_disagreement"]),
        "param_drift": float(stats["param_drift"]),
        "kl": float(stats["kl"]),
        "comm_bytes": comm_bytes,
        "up_bytes": up_bytes,
        "down_bytes": down_bytes,
        "participants": list(participants),
        "per_client_lam": stats["per_client_lam"],
        "rewards_per_client": stats["rewards_per_client"],
        "dispatches": dispatches,
        "up_nbytes": list(up_nbytes),
        "down_nbytes": down_nbytes,
        "local_steps": list(local_steps),
        "cohorts": cohorts,
    }
    if fused is not None:
        summary["fused"] = fused
    return summary


def annotate_schedule(summary: dict, *, policy: str, sim_time: float,
                      round_duration: float, dropped: Sequence[int],
                      client_seconds: Sequence[float], **extra) -> dict:
    """The sync/deadline policies' timing additions to an engine summary."""
    summary.update(policy=policy, sim_time=sim_time,
                   round_duration=round_duration, dropped=list(dropped),
                   client_seconds=[round(d, 6) for d in client_seconds],
                   **extra)
    return summary


def fedbuff_summary(*, version: int, sim_time: float, round_duration: float,
                    participants: Sequence[int], staleness: Sequence[int],
                    staleness_weights: Sequence[float], rewards,
                    rewards_per_client, comm_bytes: int, up_bytes: int,
                    down_bytes: int) -> dict:
    """One buffered-async aggregation's summary (fedbuff policy)."""
    return {
        "policy": "fedbuff",
        "version": version,
        "sim_time": sim_time,
        "round_duration": round_duration,
        "participants": list(participants),
        "staleness": list(staleness),
        "staleness_weights": [float(x) for x in staleness_weights],
        "rewards": rewards,
        "rewards_per_client": rewards_per_client,
        "comm_bytes": comm_bytes,
        "up_bytes": up_bytes,
        "down_bytes": down_bytes,
    }


# ------------------------------------------------- summary -> records
def records_from_round(summary: dict, *, round: Optional[int] = None,
                       policy: Optional[str] = None) -> List[MetricRecord]:
    """Fan one round-summary dict out into typed records.

    Emits a stable set of names under the ``round/`` (engine),
    ``comm/`` (ledger) and ``sched/`` (policy timing) prefixes; keys
    absent from the summary (e.g. ``sim_time`` on a bare engine run) are
    simply skipped.
    """
    labels = {"policy": policy} if policy else {}
    if "policy" in summary and not policy:
        labels = {"policy": summary["policy"]}
    out: List[MetricRecord] = []

    def g(name, key):
        if key in summary:
            out.append(gauge(name, summary[key], round, **labels))

    def s(name, key):
        if key in summary:
            out.append(series(name, summary[key], round, **labels))

    def c(name, key):
        if key in summary:
            out.append(counter(name, summary[key], round, **labels))

    s("round/rewards", "rewards")
    s("round/lam_mean", "lam_mean")
    g("round/lam_disagreement", "lam_disagreement")
    g("round/param_drift", "param_drift")
    g("round/kl", "kl")
    g("round/dispatches", "dispatches")
    g("round/cohorts", "cohorts")
    s("round/local_steps", "local_steps")
    c("comm/total_bytes", "comm_bytes")
    c("comm/up_bytes", "up_bytes")
    c("comm/down_bytes", "down_bytes")
    s("comm/up_nbytes", "up_nbytes")
    g("comm/down_nbytes", "down_nbytes")
    g("sched/sim_time", "sim_time")
    g("sched/round_duration", "round_duration")
    s("sched/client_seconds", "client_seconds")
    if "dropped" in summary:
        out.append(gauge("sched/dropped", len(summary["dropped"]), round,
                         **labels))
    if "staleness" in summary:
        st = summary["staleness"]
        out.append(gauge("sched/staleness_max",
                         max(st) if len(st) else 0, round, **labels))
        out.append(series("sched/staleness", st, round, **labels))
    return out
