"""Lightweight jit-entry instrumentation: dispatches, compiles, wall time.

The engine wraps every jitted program it owns with ``wrap(name, fn)``.
When no recorder is active the wrapper is a single global check on top
of the underlying call — the hot path stays uninstrumented.  Inside a
``record()`` context each call logs a ``JitSpan`` (program name, entry
wall-clock, duration, and whether THIS call triggered a compilation —
detected via the jit cache-size delta, which jax exposes as
``fn._cache_size``).

Two consumers:

  * the plan auditor (``repro.obs.audit``) counts compiles and calls per
    run and reconciles them with the ExecutionPlan;
  * ``TraceBuilder.add_host_spans`` renders the spans on the host
    wall-clock process of a Perfetto trace, so compile vs execute cost
    is visible per program.

``record()`` nests: every active recorder sees every span, so an audit
can run inside a trace capture without either stealing the other's
events.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import time
from collections import Counter
from typing import Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class JitSpan:
    name: str
    t0: float                 # perf_counter seconds at call entry
    dur: float                # seconds spent in the call (dispatch time)
    compiled: bool            # did this call grow the jit cache?


class JitLog:
    """Spans collected by one ``record()`` context."""

    def __init__(self) -> None:
        self.spans: List[JitSpan] = []

    @property
    def call_count(self) -> int:
        return len(self.spans)

    @property
    def compile_count(self) -> int:
        return sum(1 for s in self.spans if s.compiled)

    def calls_by_name(self) -> Dict[str, int]:
        return dict(Counter(s.name for s in self.spans))

    def compiles_by_name(self) -> Dict[str, int]:
        return dict(Counter(s.name for s in self.spans if s.compiled))


_STACK: List[JitLog] = []


@contextlib.contextmanager
def record(log: Optional[JitLog] = None):
    """Activate span recording for the dynamic extent of the block."""
    log = JitLog() if log is None else log
    _STACK.append(log)
    try:
        yield log
    finally:
        _STACK.remove(log)


def active() -> bool:
    return bool(_STACK)


def wrap(name: str, fn):
    """Wrap a jitted callable; spans flow to every active recorder.

    The wrapper preserves the underlying function's call semantics
    (donation, static args) — jax sees its own arguments either way.
    """
    get_size = getattr(fn, "_cache_size", None)

    @functools.wraps(fn)
    def wrapped(*args, **kwargs):
        if not _STACK:
            return fn(*args, **kwargs)
        before = get_size() if get_size is not None else -1
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        dur = time.perf_counter() - t0
        compiled = (get_size() > before) if get_size is not None else False
        span = JitSpan(name, t0, dur, compiled)
        for log in _STACK:
            log.spans.append(span)
        return out

    wrapped._jitwatch_name = name
    wrapped._wrapped_jit = fn
    return wrapped
