"""Metrics pipeline: typed records flowing into pluggable sinks.

A ``MetricsPipeline`` is the write path of the telemetry subsystem: the
engine and the scheduler policies push ``MetricRecord``s through it, and
one or more *sinks* persist them.  Three sinks ship:

  memory   append records to a list (always attached; ``pipeline.records``
           reads it back — what tests and the plan auditor consume)
  jsonl    one JSON object per line, schema-stamped (the durable
           time-series format the CI bench report parses)
  csv      flat ``schema,kind,name,round,value,labels`` rows for
           spreadsheet-shaped consumers

Sink specs are strings so they thread through ``EngineConfig`` and
benchmark CLI flags without plumbing objects: ``"memory"``,
``"jsonl:PATH"``, ``"csv:PATH"``, or a comma-separated combination.

The pipeline is intentionally dumb on the hot path: the engine computes
round statistics device-side and transfers them ONCE per round (or per
fused chunk); only the already-host-resident summary dict is fanned out
here.  Emission adds zero device syncs.
"""
from __future__ import annotations

import csv as csv_lib
import json
from typing import IO, List, Optional, Sequence

from repro.obs.records import MetricRecord, records_from_round


class MemorySink:
    """Record list in memory — the default, and the auditor's read path."""

    kind = "memory"

    def __init__(self) -> None:
        self.records: List[MetricRecord] = []

    def write(self, rec: MetricRecord) -> None:
        self.records.append(rec)

    def close(self) -> None:
        pass


class JsonlSink:
    """One schema-stamped JSON object per line."""

    kind = "jsonl"

    def __init__(self, path: str) -> None:
        self.path = path
        self._fh: Optional[IO[str]] = None

    def write(self, rec: MetricRecord) -> None:
        if self._fh is None:
            self._fh = open(self.path, "w")
        self._fh.write(json.dumps(rec.to_json()) + "\n")

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


class CsvSink:
    """Flat rows: schema,kind,name,round,value,labels (value/labels are
    JSON-encoded so vector series survive the trip)."""

    kind = "csv"
    FIELDS = ("schema", "kind", "name", "round", "value", "labels")

    def __init__(self, path: str) -> None:
        self.path = path
        self._fh: Optional[IO[str]] = None
        self._writer = None

    def write(self, rec: MetricRecord) -> None:
        if self._fh is None:
            self._fh = open(self.path, "w", newline="")
            self._writer = csv_lib.writer(self._fh)
            self._writer.writerow(self.FIELDS)
        j = rec.to_json()
        self._writer.writerow([
            j["schema"], j["kind"], j["name"], j.get("round", ""),
            json.dumps(j["value"]), json.dumps(j.get("labels", {}))])

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None
            self._writer = None


def make_sink(spec: str):
    """``"memory"`` | ``"jsonl:PATH"`` | ``"csv:PATH"`` -> a sink."""
    kind, _, arg = spec.partition(":")
    if kind == "memory":
        return MemorySink()
    if kind == "jsonl":
        if not arg:
            raise ValueError("jsonl sink needs a path: 'jsonl:PATH'")
        return JsonlSink(arg)
    if kind == "csv":
        if not arg:
            raise ValueError("csv sink needs a path: 'csv:PATH'")
        return CsvSink(arg)
    raise ValueError(f"unknown sink spec {spec!r}; "
                     "expected memory | jsonl:PATH | csv:PATH")


class MetricsPipeline:
    """Fan-out of typed records to the attached sinks."""

    def __init__(self, sinks: Sequence = ()) -> None:
        self.sinks = list(sinks)
        mems = [s for s in self.sinks if isinstance(s, MemorySink)]
        if not mems:
            mem = MemorySink()
            self.sinks.insert(0, mem)
            mems = [mem]
        self._memory = mems[0]

    @classmethod
    def from_spec(cls, spec: Optional[str]) -> "MetricsPipeline":
        """Comma-separated sink specs; None/"" -> memory only."""
        if not spec:
            return cls()
        return cls([make_sink(s.strip()) for s in spec.split(",")
                    if s.strip()])

    @property
    def records(self) -> List[MetricRecord]:
        return self._memory.records

    def emit(self, rec: MetricRecord) -> None:
        for sink in self.sinks:
            sink.write(rec)

    def emit_round(self, summary: dict, *, round: Optional[int] = None,
                   policy: Optional[str] = None) -> None:
        """The one entry point for a finished server round/aggregation."""
        for rec in records_from_round(summary, round=round, policy=policy):
            self.emit(rec)

    def emit_schedule(self, summary: dict, *,
                      round: Optional[int] = None,
                      policy: Optional[str] = None) -> None:
        """Emit only the scheduler-timing records of an annotated round
        summary.  The sync/deadline policies run ``run_round`` (which
        already emitted the ``round/`` and ``comm/`` records) and then
        add timing; this avoids double-emitting the engine records."""
        for rec in records_from_round(summary, round=round, policy=policy):
            if rec.name.startswith("sched/"):
                self.emit(rec)

    def select(self, name: str) -> List[MetricRecord]:
        """All in-memory records with the given name, in emission order."""
        return [r for r in self.records if r.name == name]

    def values(self, name: str) -> list:
        """The value trajectory of one metric name."""
        return [r.value for r in self.select(name)]

    def close(self) -> None:
        for sink in self.sinks:
            sink.close()

    def __enter__(self) -> "MetricsPipeline":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
