"""Plan auditor: reconcile ExecutionPlan predictions with observed runs.

``repro.fed.api.plan()`` predicts, before anything compiles, how a run
will execute: the chosen executor, jit dispatches per round, and exact
wire bytes per round (from the codecs' ``nbytes_static``).  This module
closes the loop — ``audit_run`` executes a trainer while counting what
ACTUALLY happens (engine dispatch counter, comms ledger, jit-cache
compile events via ``repro.obs.jitwatch``) and fails loudly when
prediction and observation drift:

    report = audit_run(trainer, rounds=4)
    report.raise_on_drift()          # PlanDriftError lists mismatches

Checks and their enforcement:

  dispatches_per_round   plan.dispatches_per_round vs the engine counter
                         delta / rounds — enforced under the sync policy
                         (the planner models the bare engine round)
  up/down_bytes_per_round  plan bytes vs ledger delta / rounds — enforced
                         under sync; deadline (dropped-client downlinks)
                         and fedbuff (version-skewed redispatch) schedules
                         are reported but not enforced
  recompiles_after_warmup  0 vs jit-cache growth during the audited run —
                         enforced whenever the auditor warmed up first
  host_transfers_per_round  observed only (the engine's one-per-round /
                         one-per-chunk discipline; pinned by tests, no
                         plan-side prediction)

CI runs a fast-lane smoke audit (``benchmarks/bench_report.py --smoke``)
over firm x {identity, int8+ef} x {per-round, fused} so a silent
regression in either the planner's model or the engine's accounting
fails the job.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.obs import jitwatch


class PlanDriftError(RuntimeError):
    """Predicted-vs-observed mismatch an audit was asked to enforce."""


@dataclasses.dataclass(frozen=True)
class AuditCheck:
    name: str
    predicted: Optional[float]
    observed: float
    enforced: bool

    @property
    def ok(self) -> bool:
        if self.predicted is None or not self.enforced:
            return True
        return abs(self.predicted - self.observed) <= 1e-6

    def to_json(self) -> dict:
        return {"name": self.name, "predicted": self.predicted,
                "observed": self.observed, "enforced": self.enforced,
                "ok": self.ok}


@dataclasses.dataclass
class AuditReport:
    algorithm: str
    executor: str
    policy: str
    uplink_codec: str
    downlink_codec: str
    rounds: int
    checks: List[AuditCheck]
    jit_calls: int
    compiles_by_name: dict

    @property
    def ok(self) -> bool:
        return all(c.ok for c in self.checks)

    def raise_on_drift(self) -> "AuditReport":
        bad = [c for c in self.checks if not c.ok]
        if bad:
            lines = [f"  {c.name}: predicted={c.predicted} "
                     f"observed={c.observed}" for c in bad]
            raise PlanDriftError(
                f"plan drift on {self.algorithm}/{self.executor}"
                f"/{self.uplink_codec} ({self.policy} policy):\n"
                + "\n".join(lines))
        return self

    def to_json(self) -> dict:
        return {
            "algorithm": self.algorithm,
            "executor": self.executor,
            "policy": self.policy,
            "uplink_codec": self.uplink_codec,
            "downlink_codec": self.downlink_codec,
            "rounds": self.rounds,
            "ok": self.ok,
            "checks": [c.to_json() for c in self.checks],
            "jit_calls": self.jit_calls,
            "compiles_by_name": dict(self.compiles_by_name),
        }


def _base_trainer(trainer):
    """Unwrap a ScheduledTrainer to the engine trainer that owns the
    counters, ledger and plan."""
    return getattr(trainer, "trainer", trainer)


def audit_run(trainer, rounds: Optional[int] = None, *,
              warmup: bool = True) -> AuditReport:
    """Run ``rounds`` through ``trainer`` and reconcile against its plan.

    ``trainer`` is a ``FederatedTrainer`` or a ``ScheduledTrainer``; the
    audited counters always live on the underlying engine trainer.  With
    ``warmup`` (default) one round — one full chunk on the fused
    executor — runs first so the audited window measures steady state
    and the recompile check is meaningful.
    """
    base = _base_trainer(trainer)
    plan = base.plan
    chunk = plan.fused_chunks[0] if plan.executor == "fused" else 1
    if rounds is None:
        rounds = 2 * chunk
    if plan.executor == "fused" and rounds % chunk:
        raise ValueError(
            f"audit rounds ({rounds}) must be a multiple of the fused "
            f"chunk ({chunk}) so per-round dispatch counts are exact")

    if warmup:
        trainer.run(chunk)

    d0 = base.jit_dispatches
    h0 = base.host_transfers
    up0, down0 = base.ledger.up_bytes, base.ledger.down_bytes
    n0 = len(base.history) if plan.policy == "sync" else None

    with jitwatch.record() as log:
        trainer.run(rounds)

    # fedbuff counts aggregations, not engine rounds; normalize by what
    # the engine actually appended when it ran engine rounds
    ran = (len(base.history) - n0) if n0 is not None else rounds
    ran = max(ran, 1)
    strict = plan.policy == "sync"
    checks = [
        AuditCheck("dispatches_per_round", plan.dispatches_per_round,
                   (base.jit_dispatches - d0) / ran, strict),
        AuditCheck("up_bytes_per_round", float(plan.up_bytes_per_round),
                   (base.ledger.up_bytes - up0) / ran, strict),
        AuditCheck("down_bytes_per_round",
                   float(plan.down_bytes_per_round),
                   (base.ledger.down_bytes - down0) / ran, strict),
        AuditCheck("recompiles_after_warmup", 0.0 if warmup else None,
                   float(log.compile_count), warmup),
        AuditCheck("host_transfers_per_round", None,
                   (base.host_transfers - h0) / ran, False),
    ]
    return AuditReport(
        algorithm=plan.algorithm,
        executor=plan.executor,
        policy=plan.policy,
        uplink_codec=plan.spec.engine.uplink_codec,
        downlink_codec=plan.spec.engine.downlink_codec,
        rounds=rounds,
        checks=checks,
        jit_calls=log.call_count,
        compiles_by_name=log.compiles_by_name(),
    )
