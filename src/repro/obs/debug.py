"""One-switch debug toggles for NaN-hunting a divergent federated run.

Editing source to flip ``jax_debug_nans`` / ``jax_enable_x64`` is the
old workflow; these helpers put both behind environment variables (read
once at ``repro.obs`` import) and CLI flags (``benchmarks/run.py
--debug-nans / --x64``):

    REPRO_DEBUG_NANS=1 PYTHONPATH=src python -m benchmarks.run --only ...
    PYTHONPATH=src python -m benchmarks.run --debug-nans --only ...

``jax_debug_nans`` makes every jitted program re-run un-jitted on a NaN
and raise at the first producing primitive; ``jax_enable_x64`` promotes
default float precision to 64-bit to separate true divergence from f32
accumulation noise.  Both are global jax config switches — flip them at
process start, not mid-run (compiled programs keep the settings they
were traced under).
"""
from __future__ import annotations

import os
from typing import Dict, Mapping, Optional

import jax

ENV_DEBUG_NANS = "REPRO_DEBUG_NANS"
ENV_X64 = "REPRO_X64"

_TRUTHY = {"1", "true", "yes", "on"}
_FALSY = {"0", "false", "no", "off", ""}


def _parse(value: str, name: str) -> bool:
    v = value.strip().lower()
    if v in _TRUTHY:
        return True
    if v in _FALSY:
        return False
    raise ValueError(f"{name}={value!r}: expected a boolean "
                     f"({sorted(_TRUTHY)} / {sorted(_FALSY)})")


def set_debug_nan(flag: bool) -> None:
    """Raise at the first NaN-producing primitive in any jitted program."""
    jax.config.update("jax_debug_nans", bool(flag))


def set_x64(flag: bool) -> None:
    """Default arrays to 64-bit floats (separate divergence from f32
    accumulation noise)."""
    jax.config.update("jax_enable_x64", bool(flag))


_applied: Optional[Dict[str, bool]] = None


def configure_from_env(env: Optional[Mapping[str, str]] = None, *,
                       force: bool = False) -> Dict[str, bool]:
    """Apply REPRO_DEBUG_NANS / REPRO_X64 if set; returns what changed.

    Runs once per process (``repro.obs`` import calls it); ``force``
    re-reads — tests use an explicit ``env`` mapping with ``force=True``.
    """
    global _applied
    if _applied is not None and not force:
        return dict(_applied)
    env = os.environ if env is None else env
    applied: Dict[str, bool] = {}
    v = env.get(ENV_DEBUG_NANS)
    if v is not None:
        flag = _parse(v, ENV_DEBUG_NANS)
        set_debug_nan(flag)
        applied["jax_debug_nans"] = flag
    v = env.get(ENV_X64)
    if v is not None:
        flag = _parse(v, ENV_X64)
        set_x64(flag)
        applied["jax_enable_x64"] = flag
    _applied = applied
    return dict(applied)
