"""Observability subsystem: metrics, simulated-time traces, plan audits.

Layered over the federated engine without touching its hot path:

  records   versioned typed records (counter/gauge/series) + the single
            round-summary constructor every producer shares
  metrics   MetricsPipeline fanning records into pluggable sinks
            (memory / jsonl / csv)
  trace     Chrome/Perfetto trace-event rendering of the simulated
            schedule and host jit wall-clock
  jitwatch  jit-entry spans: dispatches, compiles, wall time
  audit     reconcile ExecutionPlan predictions against observed runs
  debug     env/flag-wired jax_debug_nans / x64 toggles

See src/repro/obs/README.md for the schema and sink contracts.
"""
from repro.obs import debug, jitwatch
from repro.obs.audit import AuditReport, PlanDriftError, audit_run
from repro.obs.metrics import (CsvSink, JsonlSink, MemorySink,
                               MetricsPipeline, make_sink)
from repro.obs.records import (SCHEMA_VERSION, MetricRecord,
                               annotate_schedule, counter, fedbuff_summary,
                               gauge, records_from_round, round_summary,
                               series)
from repro.obs.trace import (TraceBuilder, span_seconds_by_track,
                             validate_trace)

# env-gated: a no-op unless REPRO_DEBUG_NANS / REPRO_X64 are set
debug.configure_from_env()

__all__ = [
    "AuditReport", "CsvSink", "JsonlSink", "MemorySink", "MetricRecord",
    "MetricsPipeline", "PlanDriftError", "SCHEMA_VERSION", "TraceBuilder",
    "annotate_schedule", "audit_run", "counter", "debug",
    "fedbuff_summary", "gauge", "jitwatch", "make_sink",
    "records_from_round", "round_summary", "series",
    "span_seconds_by_track", "validate_trace",
]
