"""ShapeDtypeStruct stand-ins for every lowered entry point
(MULTI-POD DRY-RUN step 2): weak-type-correct, shardable, no allocation.

``input_specs(cfg, shape, fc)`` returns the full argument pytree for the
step implied by the shape kind:
  train_4k    -> firm train step  (ClientState, frozen params, PPOBatch, aux)
  prefill_32k -> prefill          (params, tokens, aux)
  decode_*    -> serve step       (params, cache, token)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import FIRMConfig, InputShape, ModelConfig
from repro.models import transformer
from repro.models.common import split_trainable
from repro.rlhf import local as local_lib
from repro.rlhf.ppo import PPOBatch


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def seq_lens(cfg: ModelConfig, shape: InputShape):
    """(decoder_len, encoder/cross_len) for this arch at this shape."""
    if cfg.is_encoder_decoder:
        enc = shape.seq_len // cfg.encoder_len_ratio
        dec = max(8, shape.seq_len // cfg.decoder_len_ratio)
        return dec, enc
    if cfg.family == "vlm":
        return shape.seq_len, cfg.n_vision_tokens
    return shape.seq_len, 0


def aux_specs(cfg: ModelConfig, batch: int, cross_len: int,
              dtype=jnp.bfloat16) -> Optional[dict]:
    """Modality-stub inputs (DESIGN §4 carve-out)."""
    if cfg.family == "vlm":
        return {"vision": sds((batch, cross_len, cfg.d_model), dtype)}
    if cfg.is_encoder_decoder:
        return {"frames": sds((batch, cross_len, cfg.d_model), dtype)}
    return None


def param_specs(cfg: ModelConfig, dtype=jnp.bfloat16):
    key = sds((2,), jnp.uint32)
    return jax.eval_shape(
        functools.partial(transformer.init_params, cfg, dtype=dtype), key)


def state_specs(cfg: ModelConfig, fc: FIRMConfig, dtype=jnp.bfloat16):
    """(ClientState specs, frozen specs) via eval_shape — no allocation."""
    params = param_specs(cfg, dtype)

    def build(params):
        trainable, frozen = split_trainable(params)
        state = local_lib.init_client_state(trainable, fc.n_objectives,
                                            cfg.d_model, fc.kl_coef_init)
        return state, frozen

    return jax.eval_shape(build, params)


def train_batch_specs(cfg: ModelConfig, fc: FIRMConfig, shape: InputShape):
    b = shape.global_batch
    s, cross = seq_lens(cfg, shape)
    batch = PPOBatch(
        tokens=sds((b, s), jnp.int32),
        response_mask=sds((b, s), jnp.float32),
        old_logprobs=sds((b, s), jnp.float32),
        ref_logprobs=sds((b, s), jnp.float32),
        rewards=sds((b, fc.n_objectives), jnp.float32),
    )
    return batch, aux_specs(cfg, b, cross)


def prefill_specs(cfg: ModelConfig, shape: InputShape):
    b = shape.global_batch
    s, cross = seq_lens(cfg, shape)
    return sds((b, s), jnp.int32), aux_specs(cfg, b, cross)


def cache_specs(cfg: ModelConfig, shape: InputShape, dtype=jnp.bfloat16):
    b = shape.global_batch
    s, cross = seq_lens(cfg, shape)
    return jax.eval_shape(functools.partial(
        transformer.init_cache, cfg, b, s, dtype,
        n_cross=cross))


def decode_specs(cfg: ModelConfig, shape: InputShape):
    b = shape.global_batch
    return param_specs(cfg), cache_specs(cfg, shape), sds((b, 1), jnp.int32)


def input_specs(cfg: ModelConfig, shape: InputShape,
                fc: Optional[FIRMConfig] = None) -> dict:
    """Every input of the step lowered for this (arch, shape) pair."""
    fc = fc or FIRMConfig()
    if shape.kind == "train":
        state, frozen = state_specs(cfg, fc)
        batch, aux = train_batch_specs(cfg, fc, shape)
        return {"kind": "train", "state": state, "frozen": frozen,
                "batch": batch, "aux": aux}
    if shape.kind == "prefill":
        tokens, aux = prefill_specs(cfg, shape)
        return {"kind": "prefill", "params": param_specs(cfg),
                "tokens": tokens, "aux": aux}
    params, cache, token = decode_specs(cfg, shape)
    return {"kind": "decode", "params": params, "cache": cache,
            "token": token}
