"""Jittable step functions lowered by the dry-run and the drivers.

  make_train_step       — one FIRM client-local update (PPO x M -> MGDA ->
                          Adam) at full scale under (data, model)
  make_prefill_step     — sequence forward + KV/state harvest, last logits
  make_serve_step       — one decode token against the cache
  make_federated_round  — MULTI-POD: clients stacked on the 'pod' axis,
                          K local steps per client (lax.scan), then FedAvg
                          as a mean over the pod-stacked axis — GSPMD turns
                          it into the single cross-pod all-reduce of the
                          adapters that the paper's O(Cd) analysis promises.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import FIRMConfig, ModelConfig
from repro.models import transformer
from repro.rlhf import local as local_lib
from repro.rlhf.ppo import PPOBatch


def _small_metrics(m: dict) -> dict:
    """Keep only O(M) metric outputs (drop any big tensors)."""
    keep = ("losses", "lam", "lam_star", "gram", "kl", "grad_norm",
            "td_err", "ratio_mean")
    return {k: m[k] for k in keep if k in m}


def make_train_step(cfg: ModelConfig, fc: FIRMConfig):
    def train_step(state, frozen, batch: PPOBatch, aux=None):
        new_state, metrics = local_lib.firm_local_step(
            cfg, fc, state, frozen, batch, aux)
        return new_state, _small_metrics(metrics)
    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, tokens, aux=None):
        logits, cache = transformer.prefill(cfg, params, tokens, aux)
        return logits[:, -1], cache
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, cache, token):
        return transformer.decode_step(cfg, params, cache, token)
    return serve_step


def make_federated_round(cfg: ModelConfig, fc: FIRMConfig, n_pods: int):
    """stacked_state: ClientState with a leading (n_pods,) axis on every
    leaf; batches: PPOBatch with leading (n_pods, K) axes; frozen shared.
    """
    def client_k_steps(state, batches, aux_seq, frozen):
        def body(s, xs):
            b, a = xs
            s, m = local_lib.firm_local_step(cfg, fc, s, frozen, b, a)
            return s, _small_metrics(m)
        if aux_seq is None:
            def body_noaux(s, b):
                return body(s, (b, None))
            return jax.lax.scan(body_noaux, state, batches)
        return jax.lax.scan(body, state, (batches, aux_seq))

    def federated_round(stacked_state, frozen, stacked_batches, aux=None):
        # aux (modality stubs) is stacked (pods, K, ...) like the batches
        new_states, metrics = jax.vmap(
            client_k_steps,
            in_axes=(0, 0, None if aux is None else 0, None))(
            stacked_state, stacked_batches, aux, frozen)
        # FedAvg: the ONLY cross-pod collective of the round (O(Cd))
        avg = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x.mean(axis=0, keepdims=True),
                                       x.shape),
            new_states.trainable)
        return new_states._replace(trainable=avg), metrics

    return federated_round


def step_and_args(cfg: ModelConfig, shape_kind: str, fc: FIRMConfig,
                  spec: dict):
    """(fn, ordered args) for the entry point implied by the shape kind."""
    if shape_kind == "train":
        return (make_train_step(cfg, fc),
                (spec["state"], spec["frozen"], spec["batch"], spec["aux"]))
    if shape_kind == "prefill":
        return (make_prefill_step(cfg),
                (spec["params"], spec["tokens"], spec["aux"]))
    return (make_serve_step(cfg),
            (spec["params"], spec["cache"], spec["token"]))
