"""End-to-end federated alignment driver (deliverable b).

Runs the full FIRM protocol — generation, synthetic reward scoring,
multi-objective PPO, in-client regularized MGDA, FedAvg — on any assigned
architecture.  ``--preset smoke`` runs a reduced config on CPU in minutes;
``--preset full`` uses the exact assigned config (TPU-scale).

Example:
  PYTHONPATH=src python -m repro.launch.train --arch llama-3.2-1b \
      --preset smoke --rounds 4 --clients 4 --algorithm firm --beta 0.01
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.configs import get_config
from repro.configs.base import FIRMConfig
from repro.fed.engine import EngineConfig, FederatedTrainer
from repro.train import checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-3.2-1b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--algorithm", default="firm",
                    choices=["firm", "firm_unreg", "fedcmoo", "linear"])
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=3)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--objectives", type=int, default=2)
    ap.add_argument("--beta", type=float, default=0.01)
    ap.add_argument("--preference", type=float, nargs="*", default=None)
    ap.add_argument("--dirichlet-alpha", type=float, default=0.3)
    ap.add_argument("--heterogeneous-rms", action="store_true")
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="runs/train")
    # smoke-model size knobs
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=512)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.preset == "smoke":
        cfg = cfg.reduced(n_layers=args.layers, d_model=args.d_model,
                          vocab=args.vocab)
    fc = FIRMConfig(
        n_objectives=args.objectives, n_clients=args.clients,
        rounds=args.rounds, local_steps=args.local_steps,
        batch_size=args.batch_size, beta=args.beta,
        preference=tuple(args.preference) if args.preference else None,
    )
    ec = EngineConfig(algorithm=args.algorithm, max_new=args.max_new,
                      dirichlet_alpha=args.dirichlet_alpha, seed=args.seed,
                      heterogeneous_rms=args.heterogeneous_rms)
    print(f"[train] arch={cfg.name} alg={args.algorithm} C={fc.n_clients} "
          f"K={fc.local_steps} B={fc.batch_size} beta={fc.beta} "
          f"M={fc.n_objectives}")
    trainer = FederatedTrainer(cfg, fc, ec)
    os.makedirs(args.out, exist_ok=True)
    t0 = time.time()
    for r in range(args.rounds):
        s = trainer.run_round()
        print(f"round {r + 1}/{args.rounds} rewards="
              f"{np.round(s['rewards'], 4).tolist()} "
              f"lam={np.round(s['lam_mean'], 3).tolist()} "
              f"drift={s['lam_disagreement']:.4f} "
              f"comm={s['comm_bytes'] / 1e6:.2f}MB "
              f"({time.time() - t0:.0f}s)", flush=True)
    hist = [{k: (v.tolist() if isinstance(v, np.ndarray) else v)
             for k, v in s.items()} for s in trainer.history]
    with open(os.path.join(args.out, "history.json"), "w") as f:
        json.dump({"config": vars(args), "history": hist}, f, indent=1)
    checkpoint.save(os.path.join(args.out, "adapters.npz"),
                    trainer.global_trainable, step=args.rounds)
    print(f"[train] wrote {args.out}/history.json and adapters.npz")


if __name__ == "__main__":
    main()
