"""Loop-aware cost model over compiled HLO text.

``compiled.cost_analysis()`` counts each while-loop body ONCE (verified:
a 10-trip lax.scan reports 10x fewer FLOPs than its unrolled twin), which
makes its numbers useless for scan-over-layers models.  This walker
re-derives (flops, bytes, collective bytes) from the compiled SPMD module
text and multiplies every computation's cost by the trip counts of the
while loops enclosing it:

  flops  : dot ops = 2 * prod(result dims) * prod(contracted lhs dims);
           other arithmetic ops = prod(result dims)  (XLA's convention)
  bytes  : operands + results at *fusion boundaries* (internal fused ops
           produce no HBM traffic, matching XLA's bytes-accessed model)
  coll   : operand bytes of all-reduce / all-gather / reduce-scatter /
           all-to-all / collective-permute, by op kind

Trip counts come from each while's condition computation (the loop bound
is the integer constant feeding the induction-variable compare).  The
module is the per-device SPMD program, so all totals are per device.
"""
from __future__ import annotations

import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# type is either a tuple "(...)" (may contain /*index=N*/ comments, never
# nested parens) or a single token
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\([^()]*\)|\S+)\s+([\w\-]+)\(")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
# ops that move no data / cost nothing by XLA's convention
_FREE = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
         "after-all", "iota", "reshape", "broadcast", "transpose",
         "partition-id", "replica-id", "domain", "opt-barrier",
         "get-dimension-size"}
_CONTROL = {"while", "conditional", "call", "fusion", "custom-call",
            "async-start", "async-done"}


def _type_bytes(type_str: str) -> int:
    total = 0
    for ty, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(ty, 4)
    return total


def _result_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    if m.group(2):
        for d in m.group(2).split(","):
            n *= int(d)
    return n


class Instr:
    __slots__ = ("name", "type_str", "opcode", "operands", "attrs", "line")

    def __init__(self, name, type_str, opcode, operands, attrs, line):
        self.name, self.type_str, self.opcode = name, type_str, opcode
        self.operands, self.attrs, self.line = operands, attrs, line


def _split_operands(line: str, start: Optional[int] = None
                    ) -> Tuple[List[str], str]:
    """Operand names inside the top-level call parens + trailing attrs."""
    if start is None:
        start = line.find("(")
    depth, i = 0, start
    for i in range(start, len(line)):
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
            if depth == 0:
                break
    inner = line[start + 1:i]
    attrs = line[i + 1:]
    ops = re.findall(r"%([\w\.\-]+)", inner)
    return ops, attrs


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps: Dict[str, List[Instr]] = {}
        self.defs: Dict[str, Instr] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._memo: Dict[Tuple[str, bool], Tuple] = {}

    # ------------------------------------------------------------- parsing
    def _parse(self, text: str):
        cur = None
        for raw in text.splitlines():
            line = raw.rstrip()
            hdr = _COMP_HDR_RE.match(line.strip())
            if hdr and line.strip().endswith("{"):
                cur = hdr.group(2)
                self.comps[cur] = []
                if hdr.group(1):
                    self.entry = cur
                continue
            if line.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, type_str, opcode = m.groups()
            operands, attrs = _split_operands(line, start=m.end() - 1)
            ins = Instr(name, type_str, opcode, operands, attrs, line)
            self.comps[cur].append(ins)
            self.defs[name] = ins

    # ------------------------------------------------------------- helpers
    def _operand_bytes(self, ins: Instr) -> int:
        total = 0
        for o in ins.operands:
            d = self.defs.get(o)
            if d is not None:
                total += _type_bytes(d.type_str)
        return total

    def _fusion_operand_bytes(self, ins: Instr) -> int:
        """Operand traffic of a fusion, slice-aware: a parameter consumed
        ONLY by dynamic-slice ops inside the fused computation contributes
        its slice sizes, not the full buffer (scan xs reads)."""
        m = re.search(r"calls=%?([\w\.\-]+)", ins.attrs)
        if not m:
            return self._operand_bytes(ins)
        comp = self.comps.get(m.group(1), [])
        # param name -> operand index
        param_idx = {}
        for sub in comp:
            if sub.opcode == "parameter":
                pm = re.search(r"parameter\((\d+)\)", sub.line)
                if pm:
                    param_idx[sub.name] = int(pm.group(1))
        # param name -> (all consumers are dynamic-slice?, slice bytes)
        consumers: Dict[str, List[Instr]] = {p: [] for p in param_idx}
        for sub in comp:
            for o in sub.operands:
                if o in consumers:
                    consumers[o].append(sub)
        total = 0
        for i, opn in enumerate(ins.operands):
            d = self.defs.get(opn)
            if d is None:
                continue
            full = _type_bytes(d.type_str)
            # find the fused param bound to this operand position
            pname = next((p for p, j in param_idx.items() if j == i), None)
            subs = consumers.get(pname, []) if pname else []
            if subs and all(s.opcode == "dynamic-slice" for s in subs):
                total += min(full, sum(_type_bytes(s.type_str)
                                       for s in subs))
            else:
                total += full
        return total

    def _is_inplace_update(self, ins: Instr) -> bool:
        """dynamic-update-slice (raw or as fusion root) updates its buffer
        in place on real hardware — the full-buffer operand/result must
        not be charged as HBM traffic."""
        if ins.opcode == "dynamic-update-slice":
            return True
        if ins.opcode != "fusion":
            return False
        m = re.search(r"calls=%?([\w\.\-]+)", ins.attrs)
        if not m:
            return False
        out_bytes = max(_type_bytes(ins.type_str), 1)
        for sub in self.comps.get(m.group(1), []):
            if sub.opcode == "dynamic-update-slice" and \
                    _type_bytes(sub.type_str) >= 0.5 * out_bytes:
                return True
        return False

    def _inplace_bytes(self, ins: Instr) -> int:
        """read small operands + write the update region (~2x small ops).

        All operands within 2x of the result size are treated as aliased
        views of the updated buffer (the CPU backend threads bf16 AND f32
        shadows of the same cache through the loop)."""
        res = max(_type_bytes(ins.type_str), 1)
        small = sum(b for b in (_type_bytes(self.defs[o].type_str)
                                for o in ins.operands if o in self.defs)
                    if b < 0.5 * res)
        return 2 * small

    def _dot_flops(self, ins: Instr) -> float:
        out = _result_elems(ins.type_str)
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
        contracted = 1
        if m and ins.operands:
            lhs = self.defs.get(ins.operands[0])
            if lhs is not None:
                sm = _SHAPE_RE.search(lhs.type_str)
                if sm and sm.group(2):
                    dims = [int(d) for d in sm.group(2).split(",")]
                    for ci in m.group(1).split(","):
                        if ci:
                            contracted *= dims[int(ci)]
        return 2.0 * out * contracted

    def _trip_count(self, cond_comp: str) -> int:
        """Loop bound = the largest integer constant in the condition."""
        best = 1
        for ins in self.comps.get(cond_comp, []):
            for m in re.finditer(r"constant\((\d+)\)", ins.line):
                best = max(best, int(m.group(1)))
        return best

    def _called(self, ins: Instr) -> List[Tuple[str, float]]:
        """(computation, multiplier) pairs invoked by this instruction."""
        out = []
        if ins.opcode == "while":
            body = re.search(r"body=%?([\w\.\-]+)", ins.attrs)
            cond = re.search(r"condition=%?([\w\.\-]+)", ins.attrs)
            trips = self._trip_count(cond.group(1)) if cond else 1
            if body:
                out.append((body.group(1), float(trips)))
            if cond:
                out.append((cond.group(1), float(trips)))
        elif ins.opcode == "conditional":
            for m in re.finditer(r"(?:branch_computations=\{([^}]*)\}|"
                                 r"(?:true|false)_computation=%?([\w\.\-]+))",
                                 ins.attrs):
                blob = m.group(1) or m.group(2)
                for name in re.findall(r"%?([\w\.\-]+)", blob):
                    out.append((name, 1.0))
        elif ins.opcode in ("call", "fusion", "async-start"):
            m = re.search(r"(?:calls|to_apply)=%?([\w\.\-]+)", ins.attrs)
            if m:
                out.append((m.group(1), 1.0))
        return out

    # ---------------------------------------------------------------- cost
    def comp_cost(self, comp: str, fused: bool) -> Tuple[float, float, dict]:
        """(flops, bytes, coll_bytes_by_op) of one computation.

        fused=True: inside a fusion — only flops count (no HBM traffic).
        """
        key = (comp, fused)
        if key in self._memo:
            return self._memo[key]
        flops, bytes_, coll = 0.0, 0.0, {c: [0.0, 0] for c in COLLECTIVES}
        for ins in self.comps.get(comp, []):
            op = ins.opcode
            base = op.replace("-start", "").replace("-done", "")
            if base in COLLECTIVES:
                if not op.endswith("-done"):
                    b = self._operand_bytes(ins)
                    coll[base][0] += b
                    coll[base][1] += 1
                    bytes_ += b + _type_bytes(ins.type_str)
                continue
            for callee, mult in self._called(ins):
                f2, b2, c2 = self.comp_cost(callee, fused=(op == "fusion"))
                flops += mult * f2
                bytes_ += mult * b2
                for k, (b, n) in c2.items():
                    coll[k][0] += mult * b
                    coll[k][1] += mult * n
            if op in _FREE or op in ("while", "conditional", "call"):
                continue
            if op == "dot":
                flops += self._dot_flops(ins)
            elif op == "fusion":
                pass                       # flops added via callee
            elif op not in ("copy", "convert", "slice", "dynamic-slice",
                            "dynamic-update-slice", "pad", "concatenate",
                            "gather", "scatter", "select", "reduce",
                            "custom-call", "rng-bit-generator", "compare",
                            "sort", "all-to-all"):
                flops += float(_result_elems(ins.type_str))
            if op == "reduce":
                flops += float(self._operand_bytes(ins)) / 4.0
            if not fused:
                if self._is_inplace_update(ins):
                    bytes_ += self._inplace_bytes(ins)
                elif op == "dynamic-slice":
                    bytes_ += 2 * _type_bytes(ins.type_str)
                elif op == "fusion":
                    bytes_ += self._fusion_operand_bytes(ins) + \
                        _type_bytes(ins.type_str)
                else:
                    bytes_ += self._operand_bytes(ins) + \
                        _type_bytes(ins.type_str)
        out = (flops, bytes_, coll)
        self._memo[key] = out
        return out

    def totals(self) -> dict:
        if self.entry is None:
            raise ValueError("no ENTRY computation found")
        flops, bytes_, coll = self.comp_cost(self.entry, fused=False)
        return {
            "flops": flops,
            "bytes": bytes_,
            "collective_bytes": sum(b for b, _ in coll.values()),
            "collectives": {k: {"bytes": b, "count": n}
                            for k, (b, n) in coll.items()},
        }


def analyze(hlo_text: str) -> dict:
    return HloCostModel(hlo_text).totals()
