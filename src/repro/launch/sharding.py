"""Partition-spec derivation for params, optimizer state, batches, caches.

Megatron-style tensor parallelism on the 'model' axis: column-parallel
input projections (wq/wk/wv/gate/up/in_proj), row-parallel output
projections (wo/down/out_proj), vocab-sharded embedding/lm_head,
expert-parallel MoE stacks (falling back to d_ff tensor parallelism when
n_experts doesn't divide the axis).  Batch dims ride the 'data' axis.
Every rule is guarded by divisibility — dims that don't divide the mesh
axis are replicated instead (GSPMD correctness is unaffected; the roofline
shows the cost).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

# projection-name classes (the dict key *above* the 'w' leaf)
_COL = {"wq", "wk", "wv", "w_gate", "w_up", "in_proj", "w_if", "w_o",
        "router", "w", "r"}          # output-dim sharded
_ROW = {"wo", "w_down", "out_proj"}  # input-dim sharded


def _axis_size(mesh, name: str) -> int:
    return dict(mesh.shape).get(name, 1)


def _div(dim: int, size: int) -> bool:
    return size > 1 and dim % size == 0


def _path_names(path):
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return out


def param_spec(path, leaf, mesh, *, extra_leading: int = 0) -> P:
    """PartitionSpec for one parameter leaf.

    extra_leading: number of leading axes prepended outside the model
    (e.g. a client/pod stacking axis handled by the caller).
    """
    names = _path_names(path)
    msize = _axis_size(mesh, "model")
    nd = leaf.ndim - extra_leading
    stacked = "slots" in names                 # scan-stacked leading axis
    base = 1 if stacked else 0                 # first real weight dim
    spec = [None] * leaf.ndim

    name = names[-1] if names else ""
    parent = names[-2] if len(names) >= 2 else ""

    def setax(dim, axis="model"):
        if _div(leaf.shape[extra_leading + dim], _axis_size(mesh, axis)):
            spec[extra_leading + dim] = axis

    if name in ("lora_A", "lora_B"):
        pass                                    # adapters replicated (tiny)
    elif name == "embed":
        setax(0)                                # vocab-sharded
    elif parent == "lm_head":
        setax(nd - 1)
    elif parent == "experts" or (len(names) >= 3 and names[-3] == "experts"):
        # stacked expert weights: (stack?, E, d, f). Prefer expert parallel.
        e_dim = base
        if _div(leaf.shape[extra_leading + e_dim], msize):
            spec[extra_leading + e_dim] = "model"
        else:                                   # fall back: shard d_ff
            ff_dim = nd - 1 if name in ("w_gate", "w_up") else nd - 2
            setax(ff_dim)
    elif name == "w" and parent in _COL:
        setax(nd - 1)
    elif name == "w" and parent in _ROW:
        setax(base)
    elif name in ("conv_w", "conv_b"):
        setax(nd - 1)
    # norms / gates / scalars / A_log / D / dt_bias / critic stay replicated
    return P(*spec)


def param_shardings(tree, mesh, *, extra_leading: int = 0,
                    leading_axis: Optional[str] = None,
                    tensor_parallel: bool = True):
    """NamedSharding tree for a param pytree (None leaves pass through)."""
    def one(path, leaf):
        if leaf is None:
            return None
        if not tensor_parallel:
            spec = P(*([None] * leaf.ndim))
        else:
            spec = param_spec(path, leaf, mesh, extra_leading=extra_leading)
        if leading_axis is not None:
            parts = list(spec) + [None] * (leaf.ndim - len(spec))
            parts[0] = leading_axis
            spec = P(*parts)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, tree)


def replicated(mesh):
    return NamedSharding(mesh, P())


def rep_tree(tree, mesh, leading_axis: Optional[str] = None):
    def one(leaf):
        if leaf is None:
            return None
        if leading_axis is not None and getattr(leaf, "ndim", 0) >= 1:
            return NamedSharding(
                mesh, P(*([leading_axis] + [None] * (leaf.ndim - 1))))
        return replicated(mesh)
    return jax.tree_util.tree_map(one, tree)


# ------------------------------------------------------------------ batches
def _axes_size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= _axis_size(mesh, a)
    return n


def batch_spec(shape_tuple, mesh, *, extra_leading_axes=(),
               data_axes=("data",)) -> P:
    """Shard dim0 (batch) on the data axes when divisible; else rep."""
    dsize = _axes_size(mesh, data_axes)
    lead = list(extra_leading_axes)
    rest = shape_tuple[len(lead):]
    d_ax = data_axes if len(data_axes) > 1 else data_axes[0]
    spec = lead + [(d_ax if _div(rest[0], dsize) else None)] + \
        [None] * (len(rest) - 1)
    return P(*spec)


def batch_shardings(tree_of_sds, mesh, *, extra_leading_axes=(),
                    data_axes=("data",)):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(
            mesh, batch_spec(s.shape, mesh,
                             extra_leading_axes=extra_leading_axes,
                             data_axes=data_axes)),
        tree_of_sds)


# ------------------------------------------------------------------- caches
def cache_shardings(cfg: ModelConfig, cache_tree, mesh, batch: int,
                    data_axes=("data",)):
    """Decode-cache shardings: batch -> data axes, long KV seq -> 'model'
    (context-parallel decode); recurrent-state heads -> 'model'.

    When batch doesn't divide the data axes (long_500k has B=1), the KV
    sequence is sharded over ALL axes instead.
    """
    dsize = _axes_size(mesh, data_axes)
    msize = _axis_size(mesh, "model")
    b_ok = _div(batch, dsize)
    d_ax = data_axes if len(data_axes) > 1 else data_axes[0]
    all_ax = tuple(data_axes) + ("model",)

    def one(path, leaf):
        names = _path_names(path)
        name = names[-1]
        nd = leaf.ndim
        spec = [None] * nd
        stacked = "slots" in names
        off = 1 if stacked else 0               # skip the periods axis
        if name in ("k", "v", "ck", "cv"):      # (P, B, C, Hkv, Dh)
            c = leaf.shape[off + 1]
            if b_ok:
                spec[off] = d_ax
                if _div(c, msize):
                    spec[off + 1] = "model"
            else:
                if _div(c, dsize * msize):
                    spec[off + 1] = all_ax
                elif _div(c, msize):
                    spec[off + 1] = "model"
        elif name == "conv":                    # (P, B, K, C)
            if b_ok:
                spec[off] = d_ax
        elif name == "state":                   # (P, B, nh, hd, ds)
            if b_ok:
                spec[off] = d_ax
            if _div(leaf.shape[off + 1], msize):
                spec[off + 1] = "model"
        elif name == "C":                       # (P, B, H, Dh, Dh)
            if b_ok:
                spec[off] = d_ax
            if _div(leaf.shape[off + 1], msize):
                spec[off + 1] = "model"
        elif name in ("n", "m", "c", "h"):      # (P, B, ...) states
            if b_ok:
                spec[off] = d_ax
        # 'pos' scalar: replicated
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache_tree)
