from repro.launch import mesh, sharding, specs, steps  # noqa
# NOTE: repro.launch.dryrun is intentionally NOT imported here — it sets
# XLA_FLAGS for 512 host devices at import time and must only be run as
# ``python -m repro.launch.dryrun``.

__all__ = ["mesh", "sharding", "specs", "steps"]
