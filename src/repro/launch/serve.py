"""Serving driver: batched prefill + decode for any assigned architecture.

Smoke preset runs on CPU; the full configs are exercised via the dry-run.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch zamba2-1.2b \
      --preset smoke --batch 4 --prompt-len 16 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import transformer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-3.2-1b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.preset == "smoke":
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    params = transformer.init_params(cfg, key)
    b, p = args.batch, args.prompt_len
    prompt = jax.random.randint(key, (b, p), 0, cfg.vocab)
    aux = None
    if cfg.family == "vlm":
        aux = {"vision": jnp.zeros((b, cfg.n_vision_tokens, cfg.d_model),
                                   jnp.bfloat16)}
    if cfg.is_encoder_decoder:
        aux = {"frames": jnp.zeros((b, p * 2, cfg.d_model), jnp.bfloat16)}

    t0 = time.time()
    logits, cache = transformer.prefill(cfg, params, prompt, aux,
                                        cache_len=p + args.max_new)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    decode = jax.jit(lambda pr, c, t: transformer.decode_step(cfg, pr, c, t))
    tok = prompt[:, -1:]
    outs = []
    t0 = time.time()
    for i in range(args.max_new):
        lg, cache = decode(params, cache, tok)
        k = jax.random.fold_in(key, i)
        tok = jax.random.categorical(
            k, lg.astype(jnp.float32) / max(args.temperature, 1e-6),
            axis=-1)[:, None]
        outs.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = jnp.concatenate(outs, axis=1)
    print(f"[serve] arch={cfg.name} batch={b} prompt={p} new={args.max_new}")
    print(f"  prefill: {t_prefill:.3f}s  "
          f"decode: {t_decode:.3f}s "
          f"({b * args.max_new / max(t_decode, 1e-9):.1f} tok/s)")
    print("  sample token ids:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
