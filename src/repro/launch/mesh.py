"""Production mesh construction (MULTI-POD DRY-RUN step 1).

A *function*, not a module-level constant — importing this module never
touches jax device state.  Single pod: (data=16, model=16) = 256 chips of
TPU v5e.  Multi-pod: (pod=2, data=16, model=16) = 512 chips, where the
'pod' axis carries the federated clients (DESIGN §3): K FIRM local steps
run with zero cross-pod traffic and FedAvg is one all-reduce over 'pod'.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh():
    """A 1-device mesh for CPU smoke paths."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


# TPU v5e hardware constants for the roofline analysis (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW_PER_LINK = 50e9          # B/s per link
