import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) pair.

For each pair this proves the sharding config is coherent (no sharding
mismatch, no unsupported collective, memory accounted) and extracts the
roofline terms from the compiled artifact:

  compute_s    = HLO_FLOPs_per_device / 197e12        (v5e bf16 peak)
  memory_s     = HLO_bytes_per_device / 819e9         (HBM bandwidth)
  collective_s = collective_bytes_per_device / 50e9   (ICI per link)

cost_analysis() reports PER-DEVICE numbers for the SPMD module (verified
against a hand-computed einsum); collective bytes are parsed from the
compiled HLO (operand sizes of all-reduce/all-gather/reduce-scatter/
all-to-all/collective-permute).

Usage:
  python -m repro.launch.dryrun --arch all --shape all --mesh both \
      --out runs/dryrun.json
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config
from repro.configs.base import FIRMConfig
from repro.launch import hlo_cost
from repro.launch import sharding as sh
from repro.launch import specs as specs_lib
from repro.launch import steps as steps_lib
from repro.launch.mesh import (HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16,
                               make_production_mesh)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(ty: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(ty, 4)


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in the (per-device) HLO."""
    # name -> result bytes, from definition lines "  %name = f32[...]"
    def_bytes = {}
    for m in re.finditer(r"%([\w\.\-]+) = ([\w]+)\[([\d,]*)\]", hlo_text):
        def_bytes[m.group(1)] = _shape_bytes(m.group(2), m.group(3))
    totals = {c: 0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w\.\-]+ = [\w]+\[[\d,]*\][^=]*? "
                     r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                     r"collective-permute)(?:-start)?\(", stripped)
        if not m:
            continue
        op = m.group(1)
        counts[op] += 1
        # operand shapes: prefer typed operands inside the call parens
        call = stripped[m.end() - 1:]
        shapes = _SHAPE_RE.findall(call.split(")", 1)[0])
        if shapes:
            totals[op] += sum(_shape_bytes(t, d) for t, d in shapes)
        else:
            # fall back: operand names -> their definition sizes
            ops = re.findall(r"%([\w\.\-]+)", call.split(")", 1)[0])
            got = [def_bytes.get(o) for o in ops if o in def_bytes]
            if got:
                totals[op] += sum(got)
    return {"bytes_by_op": totals, "counts": counts,
            "total_bytes": sum(totals.values())}


def _shardings_for(kind, cfg, shape, mesh, spec, multi_pod, fc):
    tp = cfg.tensor_parallel
    # pure DP (tp off): the batch rides BOTH mesh axes — the model axis
    # must not duplicate work
    data_axes = ("data",) if tp else ("data", "model")
    if multi_pod:
        data_axes = ("pod",) + data_axes
    if kind == "train":
        if multi_pod:
            state_sh = sh.param_shardings(spec["state"], mesh,
                                          extra_leading=1,
                                          leading_axis="pod",
                                          tensor_parallel=tp)
            b_axes = ("data",) if tp else ("data", "model")
            batch_sh = sh.batch_shardings(spec["batch"], mesh,
                                          extra_leading_axes=("pod", None),
                                          data_axes=b_axes)
            aux_sh = (sh.batch_shardings(spec["aux"], mesh,
                                         extra_leading_axes=("pod", None),
                                         data_axes=b_axes)
                      if spec["aux"] is not None else None)
        else:
            state_sh = sh.param_shardings(spec["state"], mesh,
                                          tensor_parallel=tp)
            batch_sh = sh.batch_shardings(spec["batch"], mesh,
                                          data_axes=data_axes)
            aux_sh = (sh.batch_shardings(spec["aux"], mesh,
                                         data_axes=data_axes)
                      if spec["aux"] is not None else None)
        frozen_sh = sh.param_shardings(spec["frozen"], mesh,
                                       tensor_parallel=tp)
        return (state_sh, frozen_sh, batch_sh, aux_sh)
    if kind == "prefill":
        p_sh = sh.param_shardings(spec["params"], mesh, tensor_parallel=tp)
        t_sh = sh.batch_shardings(spec["tokens"], mesh, data_axes=data_axes)
        a_sh = (sh.batch_shardings(spec["aux"], mesh, data_axes=data_axes)
                if spec["aux"] is not None else None)
        return (p_sh, t_sh, a_sh)
    p_sh = sh.param_shardings(spec["params"], mesh, tensor_parallel=tp)
    c_sh = sh.cache_shardings(cfg, spec["cache"], mesh,
                              shape.global_batch, data_axes=data_axes)
    t_sh = sh.batch_shardings(spec["token"], mesh, data_axes=data_axes)
    return (p_sh, c_sh, t_sh)


def _multi_pod_train_spec(cfg, fc, shape, n_pods=2):
    """Pod-stacked ClientState + (pods, K, B/pods, ...) batches."""
    import dataclasses
    per_pod = dataclasses.replace(shape,
                                  global_batch=max(1, shape.global_batch
                                                   // n_pods))
    base = specs_lib.input_specs(cfg, per_pod, fc)

    def stack(tree, lead):
        return jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(lead + s.shape, s.dtype), tree)

    return {
        "kind": "train",
        "state": stack(base["state"], (n_pods,)),
        "frozen": base["frozen"],
        "batch": stack(base["batch"], (n_pods, fc.local_steps)),
        "aux": (stack(base["aux"], (n_pods, fc.local_steps))
                if base["aux"] is not None else None),
    }


def _parse_overrides(pairs):
    out = {}
    for p in pairs or []:
        k, v = p.split("=", 1)
        if v in ("True", "False"):
            out[k] = v == "True"
        else:
            try:
                out[k] = int(v)
            except ValueError:
                try:
                    out[k] = float(v)
                except ValueError:
                    out[k] = v
    return out


def run_pair(arch: str, shape_name: str, multi_pod: bool,
             fc: FIRMConfig, overrides=None) -> dict:
    import dataclasses
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = INPUT_SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "status": "ok"}
    if shape_name == "long_500k" and not cfg.subquadratic:
        rec["status"] = "skipped"
        rec["reason"] = "full-attention arch; long_500k needs sub-quadratic" \
            " attention (DESIGN §4)"
        return rec
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(mesh.devices.shape))
    if multi_pod and shape.kind == "train":
        spec = _multi_pod_train_spec(cfg, fc, shape)
        fn = steps_lib.make_federated_round(cfg, fc, n_pods=2)
        args = (spec["state"], spec["frozen"], spec["batch"], spec["aux"])
    else:
        spec = specs_lib.input_specs(cfg, shape, fc)
        fn, args = steps_lib.step_and_args(cfg, shape.kind, fc, spec)
    in_sh = _shardings_for(spec["kind"], cfg, shape, mesh, spec,
                           multi_pod, fc)
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis()
        hlo = compiled.as_text()
    # loop-aware walker: cost_analysis() counts while bodies once (see
    # hlo_cost docstring) — useless for scan-over-layers models.
    walked = hlo_cost.analyze(hlo)
    coll = {"bytes_by_op": {k: v["bytes"] for k, v
                            in walked["collectives"].items()},
            "counts": {k: v["count"] for k, v
                       in walked["collectives"].items()},
            "total_bytes": walked["collective_bytes"]}
    flops_dev = float(walked["flops"])
    bytes_dev = float(walked["bytes"])
    coll_dev = float(walked["collective_bytes"])
    # MODEL_FLOPS = 6 N D (6 N_active D for MoE)
    n_active = cfg.param_count(active_only=True)
    dec_len, enc_len = specs_lib.seq_lens(cfg, shape)
    tokens = shape.global_batch * (dec_len if shape.kind != "decode" else 1)
    fwd_bwd = 1.0 if shape.kind != "train" else 3.0
    model_flops = 2.0 * n_active * tokens * fwd_bwd  # 2ND fwd, 6ND train
    if shape.kind == "train":
        model_flops *= fc.local_steps if multi_pod else 1
    rec.update({
        "devices": n_dev,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "xla_cost_analysis": {          # loop-body-once numbers, reference
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        },
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_dev,
        "collectives": coll,
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "code_bytes": ma.generated_code_size_in_bytes,
        },
        "roofline": {
            "compute_s": flops_dev / PEAK_FLOPS_BF16,
            "memory_s": bytes_dev / HBM_BW,
            "collective_s": coll_dev / ICI_BW_PER_LINK,
        },
        "model_flops_total": model_flops,
        "model_flops_per_device": model_flops / n_dev,
        "useful_flop_ratio": (model_flops / n_dev) / max(flops_dev, 1.0),
        "params_total": cfg.param_count(),
        "params_active": n_active,
    })
    r = rec["roofline"]
    rec["dominant_term"] = max(r, key=r.get)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="runs/dryrun.json")
    ap.add_argument("--objectives", type=int, default=2)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--override", action="append", default=[],
                    help="ModelConfig field override, e.g. mlstm_chunk=64")
    args = ap.parse_args()
    overrides = _parse_overrides(args.override)

    archs = list(ASSIGNED_ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    fc = FIRMConfig(n_objectives=args.objectives,
                    local_steps=args.local_steps)

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results
            if r.get("status") in ("ok", "skipped")}

    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                key = (arch, shape_name, "2x16x16" if mp else "16x16")
                if key in done:
                    print(f"[skip-done] {key}")
                    continue
                print(f"[dryrun] {key} ...", flush=True)
                try:
                    rec = run_pair(arch, shape_name, mp, fc, overrides)
                    if overrides:
                        rec["overrides"] = overrides
                except Exception as e:  # noqa: BLE001
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": key[2], "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                results = [r for r in results
                           if (r["arch"], r["shape"], r["mesh"]) != key]
                results.append(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    extra = (f" compile={rec['compile_s']}s "
                             f"dom={rec['dominant_term']}")
                elif status == "error":
                    extra = " " + rec["error"][:200]
                print(f"[{status}] {key}{extra}", flush=True)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors "
          f"-> {args.out}")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
