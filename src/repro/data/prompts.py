"""Synthetic HH-style prompt distribution (DESIGN §5).

Prompts are token sequences drawn from per-topic unigram distributions over
disjoint-ish vocabulary bands; topics give the Dirichlet partition
something real to be non-IID over.  Deterministic given (seed, topic).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

N_TOPICS = 8


def topic_logits(vocab: int, n_topics: int = N_TOPICS,
                 seed: int = 0) -> jnp.ndarray:
    """(n_topics, vocab) unigram logits, each topic peaked on its band."""
    key = jax.random.PRNGKey(seed)
    base = jax.random.normal(key, (n_topics, vocab)) * 0.3
    band = vocab // n_topics
    for t in range(n_topics):
        base = base.at[t, t * band:(t + 1) * band].add(2.0)
    return base


def sample_prompts(key, topics: jnp.ndarray, prompt_len: int,
                   vocab: int, seed: int = 0) -> jnp.ndarray:
    """topics: (B,) int32 topic id per row -> (B, prompt_len) tokens."""
    logits = topic_logits(vocab, seed=seed)[topics]          # (B, V)
    keys = jax.random.split(key, prompt_len)

    def draw(k):
        return jax.random.categorical(k, logits, axis=-1)

    cols = jnp.stack([draw(k) for k in keys], axis=1)
    return cols.astype(jnp.int32)


class PromptDataset:
    """Per-client prompt stream with a fixed topic mixture."""

    def __init__(self, vocab: int, prompt_len: int, topic_probs,
                 seed: int = 0):
        self.vocab = vocab
        self.prompt_len = prompt_len
        self.topic_probs = jnp.asarray(topic_probs, jnp.float32)
        self.seed = seed
        self._count = 0

    def next_batch(self, batch_size: int) -> jnp.ndarray:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), self._count)
        self._count += 1
        kt, kp = jax.random.split(key)
        topics = jax.random.categorical(
            kt, jnp.log(self.topic_probs + 1e-9)[None].repeat(batch_size, 0))
        return sample_prompts(kp, topics, self.prompt_len, self.vocab,
                              seed=self.seed)
