"""Non-IID client partitioning: Dirichlet(α) over topics (paper §5 RQ1
uses Dir(0.3))."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.data.prompts import N_TOPICS, PromptDataset, sample_prompts


def dirichlet_topic_mixtures(n_clients: int, alpha: float = 0.3,
                             n_topics: int = N_TOPICS,
                             seed: int = 0) -> jnp.ndarray:
    """(C, n_topics) per-client topic mixtures; α→∞ is IID, α→0 extreme."""
    key = jax.random.PRNGKey(seed)
    return jax.random.dirichlet(key, jnp.full((n_topics,), alpha),
                                shape=(n_clients,))


def make_client_datasets(n_clients: int, vocab: int, prompt_len: int,
                         alpha: float = 0.3, seed: int = 0):
    mix = dirichlet_topic_mixtures(n_clients, alpha, seed=seed)
    return [PromptDataset(vocab, prompt_len, mix[c], seed=seed * 1000 + c)
            for c in range(n_clients)]


def sample_prompt_block(seeds: jnp.ndarray, counts: jnp.ndarray,
                        topic_probs: jnp.ndarray, batch_size: int,
                        prompt_len: int, vocab: int) -> jnp.ndarray:
    """Batched per-client prompt sampling: one vmapped draw -> (C, B, P).

    ``seeds``/``counts`` are (C,) int32 and ``topic_probs`` is (C, T).
    Reproduces each client's ``PromptDataset.next_batch`` stream exactly —
    client c's keys derive from fold_in(PRNGKey(seeds[c]), counts[c]) and
    the per-client topic logits use the same per-dataset seed — so the
    vectorized engine's rollouts match the per-client loop bit-for-bit.
    Jit-safe: embed in a jitted round body with traced counts.
    """

    def one(seed, count, probs):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), count)
        kt, kp = jax.random.split(key)
        topics = jax.random.categorical(
            kt, jnp.log(probs + 1e-9)[None].repeat(batch_size, 0))
        return sample_prompts(kp, topics, prompt_len, vocab, seed=seed)

    return jax.vmap(one)(jnp.asarray(seeds, jnp.int32),
                         jnp.asarray(counts, jnp.int32),
                         jnp.asarray(topic_probs, jnp.float32))


def heterogeneity_stat(mixtures: jnp.ndarray) -> jnp.ndarray:
    """Mean TV distance of client mixtures from the global mixture —
    an empirical proxy for the paper's ζ (Assumption 4.4)."""
    g = mixtures.mean(0)
    return 0.5 * jnp.abs(mixtures - g).sum(-1).mean()
