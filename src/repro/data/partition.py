"""Non-IID client partitioning: Dirichlet(α) over topics (paper §5 RQ1
uses Dir(0.3))."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.data.prompts import N_TOPICS, PromptDataset


def dirichlet_topic_mixtures(n_clients: int, alpha: float = 0.3,
                             n_topics: int = N_TOPICS,
                             seed: int = 0) -> jnp.ndarray:
    """(C, n_topics) per-client topic mixtures; α→∞ is IID, α→0 extreme."""
    key = jax.random.PRNGKey(seed)
    return jax.random.dirichlet(key, jnp.full((n_topics,), alpha),
                                shape=(n_clients,))


def make_client_datasets(n_clients: int, vocab: int, prompt_len: int,
                         alpha: float = 0.3, seed: int = 0):
    mix = dirichlet_topic_mixtures(n_clients, alpha, seed=seed)
    return [PromptDataset(vocab, prompt_len, mix[c], seed=seed * 1000 + c)
            for c in range(n_clients)]


def heterogeneity_stat(mixtures: jnp.ndarray) -> jnp.ndarray:
    """Mean TV distance of client mixtures from the global mixture —
    an empirical proxy for the paper's ζ (Assumption 4.4)."""
    g = mixtures.mean(0)
    return 0.5 * jnp.abs(mixtures - g).sum(-1).mean()
