from repro.data import partition, prompts  # noqa

__all__ = ["prompts", "partition"]
