"""Pure-JAX optimizers over param pytrees (no optax in this environment).

Adam/AdamW with global-norm clipping and simple LR schedules.  State is a
pytree mirroring the params, so it shards identically under pjit.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    mu: object
    nu: object
    count: jnp.ndarray


def adam_init(params) -> AdamState:
    z = jax.tree_util.tree_map(lambda x: jnp.zeros_like(x, jnp.float32),
                               params)
    z2 = jax.tree_util.tree_map(lambda x: jnp.zeros_like(x, jnp.float32),
                                params)
    return AdamState(z, z2, jnp.zeros((), jnp.int32))


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros(())
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-12))
    return jax.tree_util.tree_map(lambda x: x * scale.astype(x.dtype), tree), n


def adam_update(grads, state: AdamState, params, *, lr,
                b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
                weight_decay: float = 0.0,
                max_grad_norm: Optional[float] = None):
    """Returns (new_params, new_state, grad_norm)."""
    gn = global_norm(grads)
    if max_grad_norm is not None:
        grads, _ = clip_by_global_norm(grads, max_grad_norm)
    count = state.count + 1
    cf = count.astype(jnp.float32)
    b1c = 1.0 - b1 ** cf
    b2c = 1.0 - b2 ** cf

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * g32 * g32
        mhat = m / b1c
        vhat = v / b2c
        step = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            step = step + weight_decay * p.astype(jnp.float32)
        return m, v, (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    flat = jax.tree_util.tree_map(upd, grads, state.mu, state.nu, params)
    mu = jax.tree_util.tree_map(lambda t: t[0], flat,
                                is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree_util.tree_map(lambda t: t[1], flat,
                                is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree_util.tree_map(lambda t: t[2], flat,
                                        is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamState(mu, nu, count), gn


def sgd_update(grads, params, *, lr):
    """θ ← θ − α g  (the update TFIRM analyses)."""
    return jax.tree_util.tree_map(
        lambda p, g: (p.astype(jnp.float32)
                      - lr * g.astype(jnp.float32)).astype(p.dtype),
        params, grads)


def cosine_lr(base_lr: float, warmup: int, total: int):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(warmup, 1)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * jnp.where(s < warmup, warm, cos)
    return fn
