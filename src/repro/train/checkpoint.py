"""Pytree checkpointing to .npz (no orbax in this environment).

Leaves are flattened with '/'-joined key paths; restore rebuilds the exact
nested-dict/tuple structure from a reference tree (shape/dtype validated).
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def _key(path):
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def _paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            # npz has no bf16 cast path; store widened (dtype restored
            # from the reference tree on load)
            arr = arr.astype(np.float32)
        out[_key(path)] = arr
    return out


def save(path: str, tree, step: Optional[int] = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _paths(tree)
    if step is not None:
        flat["__step__"] = np.asarray(step)
    np.savez(path, **flat)


def restore(path: str, ref_tree):
    """Load into the structure of ``ref_tree`` (shapes/dtypes must match)."""
    with np.load(path) as data:
        flat_ref = jax.tree_util.tree_flatten_with_path(ref_tree)
        leaves = []
        for p, leaf in flat_ref[0]:
            key = _key(p)
            arr = data[key]
            if arr.shape != leaf.shape:
                raise ValueError(f"shape mismatch at {key}: "
                                 f"{arr.shape} vs {leaf.shape}")
            leaves.append(jnp.asarray(arr, leaf.dtype))
        step = int(data["__step__"]) if "__step__" in data else None
    return jax.tree_util.tree_unflatten(flat_ref[1], leaves), step
