from repro.train import checkpoint, optim  # noqa

__all__ = ["optim", "checkpoint"]
