"""Pallas TPU kernel: fused RMSNorm (+ scale).

Rows are tiled in VMEM-sized blocks with the full feature dimension
resident, so the variance reduction, rsqrt and scale happen in one pass
without an HBM round-trip for the intermediate.  Block rows default to
128 (f32 working set at d=12288: 128*12288*4 ≈ 6.3 MB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 128


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)                  # (R, d)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y.astype(o_ref.dtype) * g_ref[...])


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rmsnorm(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-5,
            block_rows: int = BLOCK_ROWS, interpret: bool = False):
    """x: (..., d), g: (d,) -> same shape as x."""
    shape = x.shape
    d = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    pad = -(-rows // block_rows) * block_rows - rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(x2.shape[0] // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, g)
    return out[:rows].reshape(shape)
