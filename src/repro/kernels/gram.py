"""Pallas TPU kernel: Gram matrix of M objective gradients (paper Eq. 2).

The MGDA subproblem needs G_ij = <g_i, g_j> over the flattened adapter
gradients — an (M, d) x (d, M) contraction with tiny M (2-8) and large d.
The roofline is pure memory bandwidth (read Md floats, write M^2), so the
kernel streams d in VMEM-sized tiles and accumulates the (M, M) product in
an f32 VMEM block that every grid step revisits.

TPU adaptation (DESIGN §3): M is padded to the 8-row sublane minimum and d
is tiled in 128-aligned chunks so each partial product is a single
(8, TILE_D) x (TILE_D, 8) MXU pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_D = 8192
M_PAD = 8


def _gram_kernel(x_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)              # (M_PAD, TILE_D)
    o_ref[...] += jax.lax.dot_general(
        x, x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gram_pallas(x: jnp.ndarray, interpret: bool = False) -> jnp.ndarray:
    """(M, d) -> (M, M) f32.  Pads M to 8 and d to a TILE_D multiple."""
    m, d = x.shape
    d_pad = -(-d // TILE_D) * TILE_D
    xp = jnp.zeros((M_PAD, d_pad), x.dtype)
    xp = jax.lax.dynamic_update_slice(xp, x, (0, 0))
    out = pl.pallas_call(
        _gram_kernel,
        grid=(d_pad // TILE_D,),
        in_specs=[pl.BlockSpec((M_PAD, TILE_D), lambda i: (0, i))],
        out_specs=pl.BlockSpec((M_PAD, M_PAD), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((M_PAD, M_PAD), jnp.float32),
        interpret=interpret,
    )(xp)
    return out[:m, :m]
