"""Pallas TPU kernel: GQA flash attention (forward).

Canonical TPU blocking: grid = (batch*q_heads, Sq/BLOCK_Q, Skv/BLOCK_K)
with the online-softmax accumulator (acc, m, l) held in VMEM scratch
across the innermost (KV) grid dimension; the output block is written on
the final KV step.  GQA is expressed in the k/v BlockSpec index maps
(query head h reads kv head h // q_per_kv) so no repeated-KV tensor is
ever materialised in HBM.  Causal and sliding-window masks are applied
from absolute block offsets.

VMEM budget per step (defaults, f32): q/o (512, 128) + k/v (512, 128) +
scratch ≈ 1.3 MB — comfortably inside the ~16 MB/core VMEM of v5e, with
128-multiple tiles for the MXU (DESIGN §3 adaptation notes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_Q = 512
BLOCK_K = 512
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale, causal, sliding_window, n_k, block_q, block_k):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (BQ, Dh)
    k = k_ref[0].astype(jnp.float32)                  # (BK, Dh)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (BQ, BK)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if sliding_window:
        mask &= q_pos - k_pos < sliding_window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                # (BQ, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "sliding_window", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, sliding_window: int = 0,
                    block_q: int = BLOCK_Q, block_k: int = BLOCK_K,
                    interpret: bool = False):
    """q: (B, Sq, Hq, Dh); k, v: (B, Skv, Hkv, Dh) -> (B, Sq, Hq, Dh)."""
    b, sq, hq, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    qpk = hq // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0, "pad seq to block size"
    n_q, n_k = sq // block_q, skv // block_k

    # (B, S, H, D) -> (B*H, S, D): head-major layout for the grid
    qt = jnp.moveaxis(q, 2, 1).reshape(b * hq, sq, dh)
    kt = jnp.moveaxis(k, 2, 1).reshape(b * hkv, skv, dh)
    vt = jnp.moveaxis(v, 2, 1).reshape(b * hkv, skv, dh)

    kernel = functools.partial(
        _flash_kernel, scale=dh ** -0.5, causal=causal,
        sliding_window=sliding_window, n_k=n_k,
        block_q=block_q, block_k=block_k)

    def kv_map(h, i, j, qpk=qpk):
        return (h // qpk, j, 0)

    out = pl.pallas_call(
        kernel,
        grid=(b * hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_k, dh), kv_map),
            pl.BlockSpec((1, block_k, dh), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, dh), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.moveaxis(out.reshape(b, hq, sq, dh), 1, 2)
