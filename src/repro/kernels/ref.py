"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gram(x: jnp.ndarray) -> jnp.ndarray:
    """(M, d) -> (M, M) Gram matrix, f32 accumulation."""
    xf = x.astype(jnp.float32)
    return xf @ xf.T


def flash_attention(q, k, v, *, causal: bool = True,
                    sliding_window: int = 0) -> jnp.ndarray:
    """q: (B, Sq, Hq, Dh); k, v: (B, Skv, Hkv, Dh) -> (B, Sq, Hq, Dh).

    Naive materialised softmax attention (the oracle).
    """
    b, sq, hq, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    qpk = hq // hkv
    kx = jnp.repeat(k, qpk, axis=2)
    vx = jnp.repeat(v, qpk, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kx.astype(jnp.float32)) * dh ** -0.5
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= qp >= kp
    if sliding_window:
        mask &= qp - kp < sliding_window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, vx.astype(jnp.float32))
    return o.astype(q.dtype)


def rmsnorm(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-5
            ) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * g)


def quantize(x2: jnp.ndarray, bits: jnp.ndarray, qmax: int = 127):
    """Blockwise symmetric quantization oracle.

    x2: (R, B) f32; bits: (R, B) uint32 rounding offsets (2**31 = exactly
    round-to-nearest).  Returns ((R, B) int8 codes, (R, 1) f32 scales).
    """
    x = x2.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / qmax, 1.0)
    r = bits.astype(jnp.float32) * (2.0 ** -32)
    q = jnp.clip(jnp.floor(x / scale + r), -qmax, qmax)
    return q.astype(jnp.int8), scale


def dequantize(codes: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    return codes.astype(jnp.float32) * scales


def abs_threshold_count(x2: jnp.ndarray, thresh) -> jnp.ndarray:
    return jnp.sum(jnp.abs(x2.astype(jnp.float32)) >= thresh
                   ).astype(jnp.float32)


def abs_threshold_mask(x2: jnp.ndarray, thresh) -> jnp.ndarray:
    x = x2.astype(jnp.float32)
    return jnp.where(jnp.abs(x) >= thresh, x, 0.0)


def ssd_scan(x, bmat, cmat, dt, da):
    """Exact SSD recurrence oracle (per-step scan).

    x: (BH, S, hd); bmat/cmat: (BH, S, ds); dt/da: (BH, S).
    h_t = exp(da_t) h_{t-1} + dt_t * x_t B_t^T;  y_t = C_t . h_t.
    """
    bh, s, hd = x.shape
    ds = bmat.shape[-1]

    def body(h, xs):
        xt, bt, ct, dtt, dat = xs
        h = jnp.exp(dat)[:, None, None] * h + \
            dtt[:, None, None] * (xt[:, :, None] * bt[:, None, :])
        y = jnp.einsum("bds,bs->bd", h, ct)
        return h, y

    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0)
               for t in (x, bmat, cmat, dt, da))
    h0 = jnp.zeros((bh, hd, ds), jnp.float32)
    _, ys = jax.lax.scan(body, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)
