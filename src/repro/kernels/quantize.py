"""Pallas TPU kernels for the comms codec hot paths (uplink compression).

quantize    — blockwise symmetric int8/int4 quantization with stochastic
              rounding.  The flat adapter delta is reshaped to (R, BLOCK)
              groups; each group gets one f32 scale (absmax / qmax) so the
              dequantization error is bounded by one quantization step per
              element.  Random bits are *passed in* as a uint32 array
              rather than drawn with ``pltpu.prng_random_bits`` so the
              identical kernel body validates under ``interpret=True`` on
              CPU (the in-kernel PRNG has no CPU lowering); on TPU the
              bits land in VMEM alongside the block.  Deterministic
              round-to-nearest is the special case bits == 2**31
              (offset exactly 0.5).
dequantize  — codes * scale back to f32.
abs_threshold_count / abs_threshold_mask
            — the two reductions behind threshold-refinement top-k
              selection (bisection on the magnitude threshold, then a
              dense mask).  O(d) streaming passes, the top-k hot path at
              production d where a full sort is memory-bound.

jnp oracles live in ref.py; dispatch wrappers in ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024          # elements per quantization group (one (8,128) tile)
ROWS_PER_STEP = 256   # grid tile: (256, 1024) f32 = 1 MB working set

_DET_BITS = jnp.uint32(2 ** 31)    # uint32 whose [0,1) image is exactly 0.5
_INV_2_32 = float(2.0 ** -32)


def _pad_rows(x2: jnp.ndarray, block_rows: int):
    rows = x2.shape[0]
    pad = -(-rows // block_rows) * block_rows - rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    return x2, rows


# ------------------------------------------------------------- quantize
def _quantize_kernel(x_ref, bits_ref, codes_ref, scale_ref, *, qmax):
    x = x_ref[...].astype(jnp.float32)                      # (R, B)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / qmax, 1.0)
    r = bits_ref[...].astype(jnp.float32) * _INV_2_32       # [0, 1)
    q = jnp.clip(jnp.floor(x / scale + r), -qmax, qmax)
    codes_ref[...] = q.astype(jnp.int8)
    scale_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("qmax", "block_rows",
                                             "interpret"))
def quantize(x2: jnp.ndarray, bits: jnp.ndarray, qmax: int = 127,
             block_rows: int = ROWS_PER_STEP, interpret: bool = False):
    """(R, BLOCK) f32 + (R, BLOCK) uint32 -> ((R, BLOCK) int8, (R, 1) f32).

    bits drive the rounding offset: uniform uint32 gives unbiased
    stochastic rounding, the constant 2**31 gives round-to-nearest.
    """
    rows, b = x2.shape
    block_rows = min(block_rows, rows)
    x2, rows = _pad_rows(x2, block_rows)
    bits, _ = _pad_rows(bits, block_rows)
    codes, scales = pl.pallas_call(
        functools.partial(_quantize_kernel, qmax=qmax),
        grid=(x2.shape[0] // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, b), lambda i: (i, 0)),
                  pl.BlockSpec((block_rows, b), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((block_rows, b), lambda i: (i, 0)),
                   pl.BlockSpec((block_rows, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct(x2.shape, jnp.int8),
                   jax.ShapeDtypeStruct((x2.shape[0], 1), jnp.float32)],
        interpret=interpret,
    )(x2, bits)
    return codes[:rows], scales[:rows]


# ----------------------------------------------------------- dequantize
def _dequantize_kernel(codes_ref, scale_ref, o_ref):
    o_ref[...] = codes_ref[...].astype(jnp.float32) * scale_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def dequantize(codes: jnp.ndarray, scales: jnp.ndarray,
               block_rows: int = ROWS_PER_STEP, interpret: bool = False):
    """(R, BLOCK) int8 + (R, 1) f32 -> (R, BLOCK) f32."""
    rows, b = codes.shape
    block_rows = min(block_rows, rows)
    codes, rows = _pad_rows(codes, block_rows)
    scales, _ = _pad_rows(scales, block_rows)
    out = pl.pallas_call(
        _dequantize_kernel,
        grid=(codes.shape[0] // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, b), lambda i: (i, 0)),
                  pl.BlockSpec((block_rows, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(codes.shape, jnp.float32),
        interpret=interpret,
    )(codes, scales)
    return out[:rows]


# ------------------------------------------------- top-k threshold ops
def _count_kernel(x_ref, t_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    hit = (jnp.abs(x) >= t_ref[0, 0]).astype(jnp.float32)
    o_ref[...] += jnp.sum(hit)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def abs_threshold_count(x2: jnp.ndarray, thresh: jnp.ndarray,
                        block_rows: int = ROWS_PER_STEP,
                        interpret: bool = False) -> jnp.ndarray:
    """Scalar count of |x| >= thresh over the whole (R, BLOCK) array.

    f32 accumulator — exact for counts < 2**24 (adapter-scale d).
    """
    rows, b = x2.shape
    block_rows = min(block_rows, rows)
    x2, rows = _pad_rows(x2, block_rows)
    t = jnp.reshape(thresh.astype(jnp.float32), (1, 1))
    out = pl.pallas_call(
        _count_kernel,
        grid=(x2.shape[0] // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, b), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(x2, t)
    # padded rows are zeros: they only count when thresh == 0
    pad_hits = jnp.where(t[0, 0] <= 0.0,
                         jnp.float32(x2.shape[0] * b - rows * b), 0.0)
    return out[0, 0] - pad_hits


def _mask_kernel(x_ref, t_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.where(jnp.abs(x) >= t_ref[0, 0], x, 0.0)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def abs_threshold_mask(x2: jnp.ndarray, thresh: jnp.ndarray,
                       block_rows: int = ROWS_PER_STEP,
                       interpret: bool = False) -> jnp.ndarray:
    """Zero out entries with |x| < thresh (dense top-k mask pass)."""
    rows, b = x2.shape
    block_rows = min(block_rows, rows)
    x2, rows = _pad_rows(x2, block_rows)
    t = jnp.reshape(thresh.astype(jnp.float32), (1, 1))
    out = pl.pallas_call(
        _mask_kernel,
        grid=(x2.shape[0] // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, b), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((block_rows, b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, jnp.float32),
        interpret=interpret,
    )(x2, t)
    return out[:rows]
