"""Pallas TPU kernels for FIRM's compute hot-spots.

gram            — (M, d) gradient Gram matrix (MGDA input, Eq. 2)
ssd             — Mamba2 SSD chunked scan (state resident in VMEM)
flash_attention — GQA blockwise-softmax attention forward
rmsnorm         — fused RMSNorm
quantize        — blockwise int8/int4 stochastic quantize / dequantize and
                  the threshold-refinement top-k passes (comms codecs)

Each kernel has its pure-jnp oracle in ref.py and a dispatch wrapper in
ops.py; validation runs in interpret mode on CPU (tests/test_kernels.py).
"""
from repro.kernels import ops, ref  # noqa

__all__ = ["ops", "ref"]
