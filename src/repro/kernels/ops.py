"""Jit'd dispatch wrappers for the Pallas kernels.

On TPU the compiled kernels run natively; elsewhere (this CPU container)
``interpret=True`` executes the kernel bodies in Python for correctness
validation, and the model code itself uses the XLA twins in
repro/models/attention.py (the dry-run lowers those).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import gram as _gram
from repro.kernels import rmsnorm as _rn
from repro.kernels import ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def gram(x: jnp.ndarray, use_pallas: bool = True) -> jnp.ndarray:
    """(M, d) stacked flat gradients -> (M, M) Gram matrix."""
    if not use_pallas:
        return ref.gram(x)
    return _gram.gram_pallas(x, interpret=_interpret())


def gram_from_pytrees(grads, use_pallas: bool = True) -> jnp.ndarray:
    """List of M gradient pytrees -> (M, M); flattens then calls gram."""
    rows = []
    for g in grads:
        leaves = [l.astype(jnp.float32).reshape(-1)
                  for l in jax.tree_util.tree_leaves(g)]
        rows.append(jnp.concatenate(leaves))
    return gram(jnp.stack(rows), use_pallas=use_pallas)


def flash_attention(q, k, v, *, causal=True, sliding_window=0,
                    use_pallas: bool = True, **kw):
    if not use_pallas:
        return ref.flash_attention(q, k, v, causal=causal,
                                   sliding_window=sliding_window)
    return _fa.flash_attention(q, k, v, causal=causal,
                               sliding_window=sliding_window,
                               interpret=_interpret(), **kw)


def rmsnorm(x, g, eps: float = 1e-5, use_pallas: bool = True):
    if not use_pallas:
        return ref.rmsnorm(x, g, eps)
    return _rn.rmsnorm(x, g, eps=eps, interpret=_interpret())


def ssd_scan(x, bmat, cmat, dt, da, *, chunk: int = 128,
             use_pallas: bool = True):
    """Chunked Mamba2 SSD scan: (BH,S,hd) x (BH,S,ds) etc -> (BH,S,hd)."""
    if not use_pallas:
        return ref.ssd_scan(x, bmat, cmat, dt, da)
    from repro.kernels import ssd as _ssd
    return _ssd.ssd_scan(x, bmat, cmat, dt, da, chunk=chunk,
                         interpret=_interpret())


# --------------------------------------------------- comms codec kernels
def quantize(x2, bits, qmax: int = 127, use_pallas: bool = True):
    """(R, B) f32 + uint32 rounding bits -> (int8 codes, (R, 1) scales)."""
    if not use_pallas:
        return ref.quantize(x2, bits, qmax)
    from repro.kernels import quantize as _q
    return _q.quantize(x2, bits, qmax=qmax, interpret=_interpret())


def dequantize(codes, scales, use_pallas: bool = True):
    if not use_pallas:
        return ref.dequantize(codes, scales)
    from repro.kernels import quantize as _q
    return _q.dequantize(codes, scales, interpret=_interpret())


def abs_threshold_count(x2, thresh, use_pallas: bool = True):
    if not use_pallas:
        return ref.abs_threshold_count(x2, thresh)
    from repro.kernels import quantize as _q
    return _q.abs_threshold_count(x2, jnp.asarray(thresh, jnp.float32),
                                  interpret=_interpret())


def abs_threshold_mask(x2, thresh, use_pallas: bool = True):
    if not use_pallas:
        return ref.abs_threshold_mask(x2, thresh)
    from repro.kernels import quantize as _q
    return _q.abs_threshold_mask(x2, jnp.asarray(thresh, jnp.float32),
                                 interpret=_interpret())


def topk_threshold(x2, k: int, iters: int = 32, use_pallas: bool = True):
    """Magnitude threshold bracket for top-k selection via bisection.

    The TPU-friendly top-k selection: ``iters`` streaming count passes
    (O(d) each, no sort).  Returns (lo, hi) with the invariant
    count(|x| >= lo) >= k > count(|x| >= hi) whenever such a bracket
    exists (count at hi may exceed k only if every entry ties at the
    max).  Entries with |x| >= hi are definite top-k members; entries in
    [lo, hi) are boundary ties that fill the remaining slots.
    """
    lo = jnp.float32(0.0)
    hi = jnp.nextafter(jnp.max(jnp.abs(x2.astype(jnp.float32))),
                       jnp.float32(jnp.inf))
    kf = jnp.float32(k)
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        cnt = abs_threshold_count(x2, mid, use_pallas=use_pallas)
        lo, hi = jnp.where(cnt >= kf, mid, lo), jnp.where(cnt >= kf, hi, mid)
    return lo, hi
