"""Jit'd dispatch wrappers for the Pallas kernels.

On TPU the compiled kernels run natively; elsewhere (this CPU container)
``interpret=True`` executes the kernel bodies in Python for correctness
validation, and the model code itself uses the XLA twins in
repro/models/attention.py (the dry-run lowers those).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import gram as _gram
from repro.kernels import rmsnorm as _rn
from repro.kernels import ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def gram(x: jnp.ndarray, use_pallas: bool = True) -> jnp.ndarray:
    """(M, d) stacked flat gradients -> (M, M) Gram matrix."""
    if not use_pallas:
        return ref.gram(x)
    return _gram.gram_pallas(x, interpret=_interpret())


def gram_from_pytrees(grads, use_pallas: bool = True) -> jnp.ndarray:
    """List of M gradient pytrees -> (M, M); flattens then calls gram."""
    rows = []
    for g in grads:
        leaves = [l.astype(jnp.float32).reshape(-1)
                  for l in jax.tree_util.tree_leaves(g)]
        rows.append(jnp.concatenate(leaves))
    return gram(jnp.stack(rows), use_pallas=use_pallas)


def flash_attention(q, k, v, *, causal=True, sliding_window=0,
                    use_pallas: bool = True, **kw):
    if not use_pallas:
        return ref.flash_attention(q, k, v, causal=causal,
                                   sliding_window=sliding_window)
    return _fa.flash_attention(q, k, v, causal=causal,
                               sliding_window=sliding_window,
                               interpret=_interpret(), **kw)


def rmsnorm(x, g, eps: float = 1e-5, use_pallas: bool = True):
    if not use_pallas:
        return ref.rmsnorm(x, g, eps)
    return _rn.rmsnorm(x, g, eps=eps, interpret=_interpret())


def ssd_scan(x, bmat, cmat, dt, da, *, chunk: int = 128,
             use_pallas: bool = True):
    """Chunked Mamba2 SSD scan: (BH,S,hd) x (BH,S,ds) etc -> (BH,S,hd)."""
    if not use_pallas:
        return ref.ssd_scan(x, bmat, cmat, dt, da)
    from repro.kernels import ssd as _ssd
    return _ssd.ssd_scan(x, bmat, cmat, dt, da, chunk=chunk,
                         interpret=_interpret())
