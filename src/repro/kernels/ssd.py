"""Pallas TPU kernel: Mamba2 SSD chunked scan (zamba2's hot-spot).

One grid step processes one (batch*head, chunk) tile: the intra-chunk
decay-masked quadratic form runs on the MXU, and the (hd, ds) SSM state is
carried across the chunk grid dimension in VMEM scratch — the state never
round-trips HBM between chunks (the fused structure of the reference CUDA
kernel, re-blocked for VMEM; DESIGN §3).

Grid = (B*nh, n_chunks) with chunk-major iteration inside each head;
B/C projections are shared across heads (ngroups=1), expressed in the
BlockSpec index maps.  Tile dims (chunk=128, hd=64, ds=64) keep the MXU
contractions 64/128-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _ssd_kernel(x_ref, b_ref, c_ref, dt_ref, da_ref, o_ref, state_ref, *,
                chunk, nh):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)          # (chunk, hd)
    bmat = b_ref[0].astype(jnp.float32)       # (chunk, ds)
    cmat = c_ref[0].astype(jnp.float32)       # (chunk, ds)
    dt = dt_ref[0].astype(jnp.float32)        # (chunk, 1)
    da = da_ref[0].astype(jnp.float32)        # (chunk, 1)

    L = jnp.cumsum(da, axis=0)                # (chunk, 1) inclusive
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    ldiff = L - L.reshape(1, chunk)           # L_i - L_j
    decay = jnp.exp(jnp.where(ii >= jj, ldiff, NEG_INF))
    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    scores = cb * decay * dt.reshape(1, chunk)
    y_intra = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    state = state_ref[...]                    # (hd, ds)
    y_inter = jax.lax.dot_general(cmat, state, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32) * \
        jnp.exp(L)
    o_ref[0] = (y_intra + y_inter).astype(o_ref.dtype)

    # state update to chunk end
    decay_end = jnp.exp(L[-1] - L)            # (chunk, 1)
    w = dt * decay_end                        # (chunk, 1)
    state_new = jax.lax.dot_general(x * w, bmat, (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    state_ref[...] = state * jnp.exp(L[-1]) + state_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, bmat, cmat, dt, da, *, chunk: int = 128,
             interpret: bool = False):
    """Chunked SSD over heads.

    x: (BH, S, hd); bmat/cmat: (BH, S, ds); dt/da: (BH, S).
    Returns y: (BH, S, hd)  (h_t = exp(da_t) h_{t-1} + dt_t x_t B_t^T;
    y_t = C_t . h_t).
    """
    bh, s, hd = x.shape
    ds = bmat.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, "pad sequence to the chunk size"
    n = s // chunk
    dt2 = dt[..., None]
    da2 = da[..., None]

    kernel = functools.partial(_ssd_kernel, chunk=chunk, nh=1)
    out = pl.pallas_call(
        kernel,
        grid=(bh, n),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1, chunk, ds), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1, chunk, ds), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1, chunk, 1), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1, chunk, 1), lambda h, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, hd), lambda h, j: (h, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), x.dtype),
        scratch_shapes=[pltpu.VMEM((hd, ds), jnp.float32)],
        interpret=interpret,
    )(x, bmat, cmat, dt2, da2)
    return out
