"""Communication accounting — the paper's O(Cd) vs O(CMd) claim (Fig. 1).

Analytic per-round byte counts for each protocol plus a ledger that
records actual array traffic during simulation so benchmark tables report
measured, not just analytic, bytes.

Measured accounting is exact per buffer dtype: a raw pytree costs
sum(size * itemsize) and an encoded ``repro.comms.Payload`` costs its
``nbytes`` (int8 codes 1 byte, packed int4 nibbles half a byte, ...) —
replacing the old f32-only ``size * 4`` assumption.  The codec-aware
analytic twins (``*_round_bytes_codec``) use each codec's
``bits_per_param`` model so benchmark tables can show analytic-vs-measured
agreement.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax

BYTES_F32 = 4


def tree_param_bytes(tree) -> int:
    """Measured bytes of a raw (uncoded) pytree: size * itemsize."""
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree) if x is not None)


def measured_bytes(obj) -> int:
    """Wire bytes of either an encoded Payload or a raw pytree."""
    if hasattr(obj, "arrays") and hasattr(obj, "nbytes"):   # Payload
        return int(obj.nbytes)
    return tree_param_bytes(obj)


def firm_round_bytes(d_trainable: int, n_clients: int, local_steps: int = 1
                     ) -> Dict[str, int]:
    """FIRM (Alg. 1): broadcast θ down + C adapted params up, ONCE per
    round regardless of K or M."""
    up = n_clients * d_trainable * BYTES_F32
    down = n_clients * d_trainable * BYTES_F32
    return {"up": up, "down": down, "total": up + down}


def fedcmoo_round_bytes(d_trainable: int, n_clients: int, n_objectives: int,
                        local_steps: int = 1, compress_rank: int = 0
                        ) -> Dict[str, int]:
    """Server-centric: per *local step*, M gradients up (or M sketches of
    size q) + λ down; plus the same param sync as FedAvg each round."""
    per_grad = (compress_rank or d_trainable) * BYTES_F32
    up = n_clients * (n_objectives * per_grad * local_steps
                      + d_trainable * BYTES_F32)
    down = n_clients * (n_objectives * BYTES_F32 * local_steps
                        + d_trainable * BYTES_F32)
    return {"up": up, "down": down, "total": up + down}


# ------------------------------------------------------- codec-aware twins
def codec_bytes_per_param(spec: str, d_trainable: int) -> float:
    """Analytic wire bytes/param of a codec spec (see repro.comms)."""
    from repro.comms.registry import make_codec
    return make_codec(spec).bits_per_param(d_trainable) / 8.0


def firm_round_bytes_codec(d_trainable: int, n_clients: int,
                           uplink_codec: str = "identity",
                           downlink_codec: str = "identity",
                           local_steps: int = 1) -> Dict[str, int]:
    """FIRM round with coded links: still O(Cd), scaled by codec rate."""
    up_bpp = codec_bytes_per_param(uplink_codec, d_trainable)
    down_bpp = codec_bytes_per_param(downlink_codec, d_trainable)
    up = int(n_clients * d_trainable * up_bpp)
    down = int(n_clients * d_trainable * down_bpp)
    return {"up": up, "down": down, "total": up + down}


def fedcmoo_round_bytes_codec(d_trainable: int, n_clients: int,
                              n_objectives: int, local_steps: int = 1,
                              uplink_codec: str = "identity",
                              downlink_codec: str = "identity"
                              ) -> Dict[str, int]:
    """FedCMOO with coded links: the M*K gradient uploads AND the param
    sync ride the uplink codec; λ broadcasts stay f32 (they are O(M))."""
    up_bpp = codec_bytes_per_param(uplink_codec, d_trainable)
    down_bpp = codec_bytes_per_param(downlink_codec, d_trainable)
    up = int(n_clients * d_trainable * up_bpp
             * (n_objectives * local_steps + 1))
    down = int(n_clients * (n_objectives * BYTES_F32 * local_steps
                            + d_trainable * down_bpp))
    return {"up": up, "down": down, "total": up + down}


# ------------------------------------------------------- time-from-bytes
# Simulated-clock models used by the scheduler subsystem (repro.fed.sched):
# transmission time derives from *measured* Payload bytes, so codec choice
# changes simulated wall-clock, not just the byte ledger.

def transmission_seconds(nbytes: float, bytes_per_sec: float) -> float:
    """Wire time of a payload over a link with the given bandwidth."""
    return float(nbytes) / max(float(bytes_per_sec), 1e-9)


def compute_seconds(tokens: float, tokens_per_sec: float) -> float:
    """Local-phase compute time at a client's processing rate."""
    return float(tokens) / max(float(tokens_per_sec), 1e-9)


def local_phase_tokens(local_steps: int, batch_size: int,
                       seq_len: int) -> int:
    """Token work of one client's local phase: K steps x B sequences of
    (prompt + generated) tokens.  Generation and the PPO update both
    scale linearly in this count at fixed model size, so one rate
    (tokens/s) captures a client's compute speed."""
    return int(local_steps) * int(batch_size) * int(seq_len)


def client_round_segments(profile, down_nbytes: float, up_nbytes: float,
                          local_steps: int, batch_size: int,
                          seq_len: int):
    """One client round as ordered (phase, seconds) segments:
    download -> local compute -> upload.  The scheduler's round time is
    the sum; the obs trace emitter renders each segment as its own span,
    so the timeline decomposes exactly into the reported total."""
    toks = local_phase_tokens(local_steps, batch_size, seq_len)
    return (
        ("download", transmission_seconds(down_nbytes,
                                          profile.down_bytes_per_sec)),
        ("compute", compute_seconds(toks, profile.tokens_per_sec)),
        ("upload", transmission_seconds(up_nbytes,
                                        profile.up_bytes_per_sec)),
    )


@dataclasses.dataclass
class CommsLedger:
    up_bytes: int = 0
    down_bytes: int = 0
    rounds: int = 0

    def send_up(self, obj):
        """obj: encoded Payload or raw pytree — measured either way."""
        self.up_bytes += measured_bytes(obj)

    def send_down(self, obj):
        self.down_bytes += measured_bytes(obj)

    def next_round(self):
        self.rounds += 1

    @property
    def total(self) -> int:
        return self.up_bytes + self.down_bytes
