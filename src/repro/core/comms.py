"""Communication accounting — the paper's O(Cd) vs O(CMd) claim (Fig. 1).

Analytic per-round byte counts for each protocol plus a ledger that
records actual array traffic during simulation so benchmark tables report
measured, not just analytic, bytes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax

BYTES_F32 = 4


def tree_param_bytes(tree) -> int:
    return sum(x.size * BYTES_F32 for x in jax.tree_util.tree_leaves(tree)
               if x is not None)


def firm_round_bytes(d_trainable: int, n_clients: int, local_steps: int = 1
                     ) -> Dict[str, int]:
    """FIRM (Alg. 1): broadcast θ down + C adapted params up, ONCE per
    round regardless of K or M."""
    up = n_clients * d_trainable * BYTES_F32
    down = n_clients * d_trainable * BYTES_F32
    return {"up": up, "down": down, "total": up + down}


def fedcmoo_round_bytes(d_trainable: int, n_clients: int, n_objectives: int,
                        local_steps: int = 1, compress_rank: int = 0
                        ) -> Dict[str, int]:
    """Server-centric: per *local step*, M gradients up (or M sketches of
    size q) + λ down; plus the same param sync as FedAvg each round."""
    per_grad = (compress_rank or d_trainable) * BYTES_F32
    up = n_clients * (n_objectives * per_grad * local_steps
                      + d_trainable * BYTES_F32)
    down = n_clients * (n_objectives * BYTES_F32 * local_steps
                        + d_trainable * BYTES_F32)
    return {"up": up, "down": down, "total": up + down}


@dataclasses.dataclass
class CommsLedger:
    up_bytes: int = 0
    down_bytes: int = 0
    rounds: int = 0

    def send_up(self, tree):
        self.up_bytes += tree_param_bytes(tree)

    def send_down(self, tree):
        self.down_bytes += tree_param_bytes(tree)

    def next_round(self):
        self.rounds += 1

    @property
    def total(self) -> int:
        return self.up_bytes + self.down_bytes
