"""FedCMOO baseline — server-centric conflict resolution (Askin et al. 2024,
adapted to alignment as in paper §5 RQ1).

Protocol per step: every client sends its M objective gradients (optionally
sketch-compressed) to the server; the server averages them, solves ONE
MGDA problem, and broadcasts the global λ back; clients then apply
g_c = Σ_j λ_j g_j^c.  Communication is O(CMd) uncompressed, O(CMq) with a
rank-q sketch — plus the extra λ round-trip every step.

The paper's RQ1 comparison disables compression; we implement both so the
convergence-vs-compression-error trade-off (their q term) is measurable.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import mgda


def flatten_grads(grads: Sequence) -> jnp.ndarray:
    """List of M pytrees -> (M, d) matrix (f32)."""
    rows = []
    for g in grads:
        leaves = jax.tree_util.tree_leaves(g)
        rows.append(jnp.concatenate(
            [l.astype(jnp.float32).reshape(-1) for l in leaves]))
    return jnp.stack(rows)


def sketch(flat: jnp.ndarray, q: int, key) -> jnp.ndarray:
    """JL sketch: (M, d) -> (M, q); Gram is approximately preserved."""
    d = flat.shape[1]
    s = jax.random.normal(key, (d, q), jnp.float32) / jnp.sqrt(q)
    return flat @ s


def server_solve(client_grads: Sequence[jnp.ndarray], beta: float = 0.0,
                 trace_normalize: bool = True, solver: str = "pgd",
                 iters: int = 100) -> jnp.ndarray:
    """Server step: average client gradient matrices, solve one MGDA.

    client_grads: list over clients of (M, d|q) matrices (raw or sketched).
    Returns the global λ broadcast to all clients.  β defaults to 0 —
    FedCMOO does not regularise; disagreement drift is avoided *by design*
    (single server λ) at the cost of O(CMd) communication.
    """
    avg = sum(client_grads) / len(client_grads)
    G = mgda.gram_matrix(avg)
    return mgda.solve(G, beta, trace_normalize=trace_normalize,
                      solver=solver, iters=iters)


def fedcmoo_round_lambda(per_client_grads: Sequence[Sequence],
                         compress_rank: Optional[int] = None,
                         key=None, **solve_kw) -> jnp.ndarray:
    """One conflict-resolution round.  per_client_grads[c] = M pytrees."""
    mats = [flatten_grads(g) for g in per_client_grads]
    if compress_rank:
        keys = jax.random.split(key, len(mats))
        # all clients must use the SAME sketch for the Gram to be consistent
        mats = [sketch(m, compress_rank, keys[0]) for m in mats]
    return server_solve(mats, **solve_kw)


def stack_grads_flat(grads: Sequence, m: int) -> jnp.ndarray:
    """M stacked gradient trees (leading (C,) axis) -> (C, M, d) f32.

    Row (c, j) is bit-identical to ``flatten_grads`` applied to client
    c's j-th gradient tree — the batched form of the server exchange's
    per-client flatten, so the stacked codec boundary can encode all
    C x M gradient uploads in one dispatch.
    """
    mats = [jnp.concatenate(
        [l.astype(jnp.float32).reshape(l.shape[0], -1)
         for l in jax.tree_util.tree_leaves(grads[j])], axis=1)
        for j in range(m)]
    return jnp.stack(mats, axis=1)


def fedcmoo_round_lambda_stacked(stacked: jnp.ndarray,
                                 compress_rank: Optional[int] = None,
                                 key=None, **solve_kw) -> jnp.ndarray:
    """Batched-exchange twin of ``fedcmoo_round_lambda``.

    ``stacked`` is the (C, M, d) array of per-client gradient matrices as
    the server decodes them — the stacked codec boundary feeds the λ
    solve directly, with no per-client pytree rebuild or host loop.  The
    client average keeps ``server_solve``'s list-sum association so both
    entry points return identical λ.
    """
    mats = [stacked[c] for c in range(stacked.shape[0])]
    if compress_rank:
        keys = jax.random.split(key, len(mats))
        # all clients must use the SAME sketch for the Gram to be
        # consistent (and for λ parity with fedcmoo_round_lambda)
        mats = [sketch(m, compress_rank, keys[0]) for m in mats]
    return server_solve(mats, **solve_kw)
