"""FIRM core: the paper's contribution as composable JAX modules."""
from repro.core import comms, drift, fedavg, fedcmoo, firm, mgda  # noqa

__all__ = ["mgda", "firm", "fedavg", "fedcmoo", "drift", "comms"]
