"""Multi-objective disagreement drift diagnostics (paper §3, Rmk 4.8,
Lemma F.6).  These metrics drive the RQ2 experiments and the property
tests that check the paper's bounds empirically."""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def lambda_disagreement(lams: jnp.ndarray) -> dict:
    """lams: (C, M) per-client MGDA weights.

    Returns mean/max pairwise ||λ_c − λ_c'||₂ and the deviation from the
    mean λ̄ — the quantity inside T_{1,1}^{disagr-drift} (Eq. 7).
    """
    c = lams.shape[0]
    diff = lams[:, None, :] - lams[None, :, :]            # (C, C, M)
    pd = jnp.sqrt(jnp.sum(diff ** 2, -1) + 1e-30)
    off = pd[jnp.triu_indices(c, k=1)]
    bar = lams.mean(0)
    return {
        "pairwise_mean": off.mean() if off.size else jnp.zeros(()),
        "pairwise_max": off.max() if off.size else jnp.zeros(()),
        "to_mean": jnp.sqrt(((lams - bar) ** 2).sum(-1)).mean(),
    }


def gradient_bound_R(grads: Sequence) -> jnp.ndarray:
    """R = max_j ||g_j||₂ over objectives (Lemma F.5 empirical stand-in)."""
    norms = [jnp.sqrt(sum(jnp.vdot(l, l).real
                          for l in jax.tree_util.tree_leaves(g)))
             for g in grads]
    return jnp.max(jnp.stack(norms))


def lemma_f6_check(grads_c: Sequence, grads_c2: Sequence,
                   lam_c: jnp.ndarray, lam_c2: jnp.ndarray,
                   beta: float) -> dict:
    """Empirical check of Lemma F.6:
       ||λ*c − λ*c'|| ≤ (4RM/β) max_j ||g_j^c − g_j^c'||.
    NOTE: with App.-A trace normalisation the effective gradients are
    g/sqrt(tr(G)/M); we report both raw and the bound certificate."""
    m = len(grads_c)
    r = jnp.maximum(gradient_bound_R(grads_c), gradient_bound_R(grads_c2))
    max_diff = jnp.max(jnp.stack([
        jnp.sqrt(sum(jnp.vdot(a - b, a - b).real
                     for a, b in zip(jax.tree_util.tree_leaves(gc),
                                     jax.tree_util.tree_leaves(gc2))))
        for gc, gc2 in zip(grads_c, grads_c2)]))
    lhs = jnp.linalg.norm(lam_c - lam_c2)
    rhs = (4.0 * r * m / beta) * max_diff
    return {"lhs": lhs, "rhs": rhs, "R": r, "max_grad_diff": max_diff}


def param_drift(client_trees: Sequence) -> jnp.ndarray:
    """Mean pairwise L2 distance between client parameter trees."""
    c = len(client_trees)
    flats = [jnp.concatenate([l.astype(jnp.float32).reshape(-1)
                              for l in jax.tree_util.tree_leaves(t)])
             for t in client_trees]
    total, n = 0.0, 0
    for i in range(c):
        for j in range(i + 1, c):
            total = total + jnp.linalg.norm(flats[i] - flats[j])
            n += 1
    return total / max(n, 1)


def param_drift_stacked(stacked_tree) -> jnp.ndarray:
    """``param_drift`` over a stacked pytree with a leading client axis.

    One jittable program (no per-pair dispatches), device-resident for
    the vectorized engine's once-per-round host transfer.  Distances are
    computed subtract-first row-by-row — O(Cd) peak memory instead of a
    (C, C, d) broadcast, and none of the Gram-identity cancellation that
    matters when clients have drifted only slightly apart.
    """
    leaves = jax.tree_util.tree_leaves(stacked_tree)
    c = leaves[0].shape[0]
    if c < 2:
        return jnp.zeros(())
    flat = jnp.concatenate([l.astype(jnp.float32).reshape(c, -1)
                            for l in leaves], axis=1)          # (C, d)

    def row(i, acc):
        # distances from client i to everyone (the i==i term is 0)
        d2 = jnp.sum((flat - flat[i]) ** 2, -1)                # (C,)
        return acc + jnp.sqrt(d2).sum()

    total = jax.lax.fori_loop(0, c, row, jnp.zeros((), jnp.float32))
    return total / 2.0 / (c * (c - 1) // 2)
