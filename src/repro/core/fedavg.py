"""FedAvg aggregation — host-side (simulation) and collective (mesh) forms."""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def fedavg(trees: Sequence):
    """θ ← (1/C) Σ_c θ_c over a list of client param pytrees."""
    c = len(trees)
    out = trees[0]
    for t in trees[1:]:
        out = jax.tree_util.tree_map(lambda a, b: a + b, out, t)
    return jax.tree_util.tree_map(lambda a: a / c, out)


def fedavg_weighted(trees: Sequence, weights: Sequence[float]):
    w = jnp.asarray(weights, jnp.float32)
    w = w / w.sum()
    out = jax.tree_util.tree_map(lambda a: a * w[0], trees[0])
    for i, t in enumerate(trees[1:], start=1):
        out = jax.tree_util.tree_map(lambda a, b: a + b * w[i], out, t)
    return out


def stack_trees(trees: Sequence):
    """C identically-structured pytrees -> one pytree with a leading client
    axis — the stacked-client representation of the vectorized engine."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def unstack_tree(tree, n: int):
    """Inverse of ``stack_trees``: split the leading client axis back into
    a list of n per-client pytrees."""
    return [jax.tree_util.tree_map(lambda x, i=i: x[i], tree)
            for i in range(n)]


def fedavg_stacked(stacked):
    """θ ← (1/C) Σ_c θ_c over the leading client axis in one batched tree
    op (the vectorized form of ``fedavg``)."""
    return jax.tree_util.tree_map(lambda x: x.mean(axis=0), stacked)


def staleness_weights(staleness, pow: float = 0.5) -> jnp.ndarray:
    """FedBuff-style staleness discounting: w_i ∝ (1 + s_i)^-pow.

    ``staleness`` is the per-arrival (C,) count of server versions that
    advanced while each client trained.  Weights always sum to 1, and at
    zero staleness they reduce to the uniform 1/C — so staleness-weighted
    aggregation of a synchronous barrier is *exactly* FedAvg.
    """
    s = jnp.asarray(staleness, jnp.float32)
    w = (1.0 + s) ** (-jnp.asarray(pow, jnp.float32))
    return w / w.sum()


def fedavg_flat_weighted(flats: jnp.ndarray, weights: jnp.ndarray
                         ) -> jnp.ndarray:
    """(C, d) stacked flat deltas x (C,) weights -> (d,) aggregate.

    The flat-vector twin of ``fedavg_weighted`` used at the engine's
    codec Payload boundary (one matvec, no per-client tree ops).
    """
    return jnp.asarray(weights, jnp.float32) @ flats


def fedavg_collective(tree, axis_name: str = "pod"):
    """Cross-pod FedAvg as a single all-reduce (the O(Cd) collective).

    Use inside shard_map/pjit over the federated 'pod' mesh axis; this is
    the ONLY cross-pod communication a FIRM round emits (DESIGN §3).
    """
    return jax.tree_util.tree_map(
        lambda x: jax.lax.pmean(x, axis_name), tree)
