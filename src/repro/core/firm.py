"""FIRM in-client gradient resolution (paper Alg. 1 / Alg. 2 Eq. 12).

``resolve`` is the heart of the paper: given M per-objective gradients it
(1) forms the Gram matrix (Pallas kernel on TPU, jnp fallback elsewhere),
(2) trace-normalises (App. A), (3) solves the β-regularised MGDA QP
(Eq. 1/9, or the preference-weighted Eq. 3), (4) optionally smooths λ with
the η_t schedule of Alg. 2, and (5) returns the single consensus direction
g = Σ_j λ_j g_j that the client applies locally.  No gradient ever leaves
the client — only adapted parameters are communicated (O(Cd)).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import FIRMConfig
from repro.core import mgda


class ResolveResult(NamedTuple):
    direction: object            # pytree: Σ λ_j g_j
    lam: jnp.ndarray             # λ used for the update (post-smoothing)
    lam_star: jnp.ndarray        # raw QP solution λ*
    gram: jnp.ndarray            # unnormalised Gram matrix (M, M)


def resolve(grads: Sequence, fc: FIRMConfig,
            prev_lam: Optional[jnp.ndarray] = None,
            eta: Optional[jnp.ndarray] = None,
            gram_fn=None,
            preference: Optional[jnp.ndarray] = None) -> ResolveResult:
    """Resolve M per-objective gradients into one direction (Eq. 1).

    grads: list of M gradient pytrees (or stacked (M, d) array).
    prev_lam/eta: λ smoothing state (Alg. 2 Eq. 12); eta=1 disables.
    gram_fn: override for the Gram computation (e.g. the Pallas kernel).
    preference: (M,) array overriding ``fc.preference`` — a *traced*
        preference vector, so per-client p vectors can ride one vmapped
        trace instead of forcing a retrace per static config.
    """
    G = (gram_fn or mgda.gram_matrix)(grads)
    if preference is not None:
        pref = jnp.asarray(preference, jnp.float32)
    else:
        pref = (jnp.asarray(fc.preference, jnp.float32)
                if fc.preference is not None else None)
    lam_star = mgda.solve(G, fc.beta, preference=pref,
                          trace_normalize=fc.trace_normalize,
                          solver=fc.solver, iters=fc.solver_iters)
    if fc.lambda_smoothing and prev_lam is not None:
        e = eta if eta is not None else jnp.asarray(fc.eta0, jnp.float32)
        lam = (1.0 - e) * prev_lam + e * lam_star
    else:
        lam = lam_star
    direction = mgda.combine(grads, lam)
    return ResolveResult(direction, lam, lam_star, G)


def eta_schedule(t: jnp.ndarray) -> jnp.ndarray:
    """η_t = 1/t (App. F.3.3), with η_1 = 1."""
    return 1.0 / jnp.maximum(t.astype(jnp.float32), 1.0)


def staleness_beta(beta: float, staleness, gain: float = 0.5,
                   cap: float = 8.0) -> float:
    """β_eff = β · min(1 + gain·s, cap) — staleness-aware regularization.

    Under buffered-async aggregation a client training from a version s
    rounds behind the server drifts further from consensus; FIRM's
    in-client regularizer β is exactly the drift-mitigation knob (Thm 4.5),
    so the async scheduler scales it with the client's observed staleness
    instead of bolting on a separate correction term.  ``gain`` = 0
    disables the coupling (β_eff = β); ``cap`` bounds the multiplier so a
    deeply stale client still makes progress on its own objectives.
    """
    mult = min(1.0 + gain * float(staleness), cap)
    return float(beta) * mult
