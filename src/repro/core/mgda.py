"""Regularized MGDA subproblem solvers (paper Eq. 1-3, 9, App. A/H).

Solve  λ* = argmin_{λ∈Δ_M}  λᵀ (Ĝ + R) λ
where Ĝ is the (optionally trace-normalised, App. A) Gram matrix of the M
objective gradients and R is either the uniform regulariser (β/2)·I (Eq. 2)
or the preference regulariser Diag(p⁻¹) (Eq. 3 / App. H).

All solvers are jit-safe (fixed iteration counts, lax control flow):
  - closed_form_m2 : exact for M = 2 (1-D quadratic on a segment)
  - pgd           : projected gradient descent with sort-based simplex
                    projection (exact for strongly-convex Q as iters → ∞)
  - frank_wolfe   : FW with exact line search for the quadratic
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp


def gram_matrix(grads) -> jnp.ndarray:
    """Gram matrix of M gradient pytrees: G_ij = <g_i, g_j> (f32).

    ``grads`` is a list of pytrees (one per objective) or a stacked
    (M, d) array.
    """
    if isinstance(grads, jnp.ndarray):
        return (grads.astype(jnp.float32) @ grads.astype(jnp.float32).T)
    m = len(grads)
    leaves = [jax.tree_util.tree_leaves(g) for g in grads]

    def dot(i, j):
        return sum(jnp.vdot(a.astype(jnp.float32), b.astype(jnp.float32))
                   for a, b in zip(leaves[i], leaves[j]))

    rows = []
    for i in range(m):
        rows.append(jnp.stack([dot(i, j) for j in range(m)]))
    return jnp.stack(rows)


def regularize(G: jnp.ndarray, beta: float,
               preference: Optional[jnp.ndarray] = None,
               trace_normalize: bool = True) -> jnp.ndarray:
    """Ĝ + (β/2)I  or  Ĝ + Diag(p⁻¹)  (Eq. 9 / Eq. 3)."""
    m = G.shape[0]
    if trace_normalize:
        G = G / jnp.maximum(jnp.trace(G) / m, 1e-12)      # App. A
    if preference is not None:
        # Eq. 3 / App. H: Diag(p^{-1}) replaces the uniform (β/2)I.
        p = jnp.asarray(preference, jnp.float32)
        return G + jnp.diag(1.0 / jnp.maximum(p, 1e-9))
    return G + 0.5 * beta * jnp.eye(m, dtype=G.dtype)


def project_simplex(v: jnp.ndarray) -> jnp.ndarray:
    """Euclidean projection onto the probability simplex (sort method)."""
    m = v.shape[-1]
    u = jnp.sort(v)[..., ::-1]
    css = jnp.cumsum(u, axis=-1)
    k = jnp.arange(1, m + 1, dtype=v.dtype)
    cond = u + (1.0 - css) / k > 0
    rho = jnp.sum(cond, axis=-1)
    theta = (jnp.take_along_axis(css, rho[None] - 1, axis=-1)[..., 0] - 1.0) \
        / rho.astype(v.dtype)
    return jnp.maximum(v - theta, 0.0)


def solve_qp_pgd(Q: jnp.ndarray, iters: int = 100,
                 lam0: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """min_{λ∈Δ} λᵀQλ by projected gradient descent."""
    m = Q.shape[0]
    lam = lam0 if lam0 is not None else jnp.full((m,), 1.0 / m, jnp.float32)
    lip = 2.0 * jnp.linalg.norm(Q, ord="fro") + 1e-9
    step = 1.0 / lip

    def body(_, lam):
        grad = 2.0 * Q @ lam
        return project_simplex(lam - step * grad)

    return jax.lax.fori_loop(0, iters, body, lam)


def solve_qp_m2(Q: jnp.ndarray) -> jnp.ndarray:
    """Exact minimiser on Δ_2: λ = [t, 1-t]."""
    a = Q[0, 0] - 2.0 * Q[0, 1] + Q[1, 1]
    t = jnp.where(a > 1e-12, (Q[1, 1] - Q[0, 1]) / jnp.maximum(a, 1e-12), 0.5)
    t = jnp.clip(t, 0.0, 1.0)
    return jnp.stack([t, 1.0 - t])


def solve_qp_frank_wolfe(Q: jnp.ndarray, iters: int = 100) -> jnp.ndarray:
    m = Q.shape[0]
    lam = jnp.full((m,), 1.0 / m, jnp.float32)

    def body(_, lam):
        grad = 2.0 * Q @ lam
        s = jax.nn.one_hot(jnp.argmin(grad), m, dtype=jnp.float32)
        d = s - lam
        # exact line search for quadratic: γ* = -λᵀQd / dᵀQd
        denom = d @ Q @ d
        gamma = jnp.where(denom > 1e-12,
                          jnp.clip(-(lam @ Q @ d) / jnp.maximum(denom, 1e-12),
                                   0.0, 1.0),
                          0.0)
        return lam + gamma * d

    return jax.lax.fori_loop(0, iters, body, lam)


_SOLVERS = {"pgd": solve_qp_pgd, "closed_form_m2": solve_qp_m2,
            "frank_wolfe": solve_qp_frank_wolfe}


def solve(G: jnp.ndarray, beta: float,
          preference: Optional[jnp.ndarray] = None,
          trace_normalize: bool = True, solver: str = "pgd",
          iters: int = 100) -> jnp.ndarray:
    """End-to-end: regularise G and return λ* ∈ Δ_M."""
    Q = regularize(G, beta, preference, trace_normalize)
    if solver == "closed_form_m2":
        if G.shape[0] != 2:
            raise ValueError("closed_form_m2 requires M=2")
        return solve_qp_m2(Q)
    if solver == "frank_wolfe":
        return solve_qp_frank_wolfe(Q, iters)
    return solve_qp_pgd(Q, iters)


def combine(grads, lam: jnp.ndarray):
    """g = Σ_j λ_j g_j over pytrees (or a stacked (M, d) array)."""
    if isinstance(grads, jnp.ndarray):
        return jnp.einsum("m,md->d", lam, grads)
    out = jax.tree_util.tree_map(lambda x: lam[0].astype(x.dtype) * x,
                                 grads[0])
    for j in range(1, len(grads)):
        out = jax.tree_util.tree_map(
            lambda a, x: a + lam[j].astype(x.dtype) * x, out, grads[j])
    return out
