"""Roofline report (deliverable g): reads runs/dryrun.json and emits the
per-(arch x shape x mesh) table of roofline terms + dominant bottleneck.

Run the dry-run first:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out runs/dryrun.json
"""
from __future__ import annotations

import json
import os

from benchmarks.common import row

DRYRUN = os.environ.get("DRYRUN_JSON", "runs/dryrun.json")


def _bottleneck_note(rec) -> str:
    dom = rec["dominant_term"]
    if dom == "memory_s":
        return "increase arithmetic intensity (fusion/remat policy/dtype)"
    if dom == "collective_s":
        return "reduce resharding (sharding axes, overlap collectives)"
    return "compute-bound: good (raise MXU utilisation via tiling)"


def bench_roofline_table():
    if not os.path.exists(DRYRUN):
        return row("roofline_table", 0.0,
                   {"error": f"{DRYRUN} missing; run the dry-run first"})
    recs = json.load(open(DRYRUN))
    ok = [r for r in recs if r["status"] == "ok"]
    skipped = [r for r in recs if r["status"] == "skipped"]
    table = []
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        rf = r["roofline"]
        table.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "compute_s": rf["compute_s"], "memory_s": rf["memory_s"],
            "collective_s": rf["collective_s"],
            "dominant": r["dominant_term"].replace("_s", ""),
            "useful_flop_ratio": r["useful_flop_ratio"],
            "temp_GB_per_dev": r["memory"]["temp_bytes"] / 1e9,
        })
    doms = {}
    for t in table:
        doms[t["dominant"]] = doms.get(t["dominant"], 0) + 1
    return row("roofline_table", 0.0, {
        "pairs_ok": len(ok), "pairs_skipped": len(skipped),
        "dominant_counts": doms,
        "note": "full table in EXPERIMENTS.md §Roofline",
    })


def bench_roofline_per_pair():
    """Emit one CSV row per (arch, shape) single-pod baseline."""
    if not os.path.exists(DRYRUN):
        return []
    recs = json.load(open(DRYRUN))
    rows = []
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] != "ok" or r["mesh"] != "16x16":
            continue
        rf = r["roofline"]
        rows.append(row(
            f"roofline[{r['arch']}|{r['shape']}]",
            rf[r["dominant_term"]] * 1e6,       # dominant term in us
            {"compute_s": rf["compute_s"], "memory_s": rf["memory_s"],
             "collective_s": rf["collective_s"],
             "dominant": r["dominant_term"],
             "useful": r["useful_flop_ratio"],
             "fix": _bottleneck_note(r)}))
    return rows


ALL = [bench_roofline_table]
