"""Shared helpers for the benchmark harness.

Every benchmark emits ``name,us_per_call,derived`` CSV rows (derived is a
compact json-ish summary of the paper-relevant quantities).

Trainers are built through the declarative front door
(``repro.fed.api``: RunSpec -> plan() -> build()), so every benchmark
cell runs exactly the executor the plan resolves — identical numbers to
direct ``FederatedTrainer`` construction (``tests/test_plan.py`` pins
this), with the plan available for inspection via ``trainer.plan``.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.configs import get_config
from repro.configs.base import FIRMConfig
from repro.fed import api
from repro.fed.api import EngineConfig, RunSpec

# Observability options shared by every benchmark module.  ``run.py`` (or
# a standalone ``__main__``) fills these from CLI flags via
# ``parse_cli_options``; benchmark cells read them through
# ``cell_sink_spec`` / ``trace_path``.  Defaults keep telemetry off, so
# plain imports and tests see the pre-obs behaviour.
OPTIONS = {
    "trace_out": None,      # directory for Perfetto trace-event JSON files
    "metrics_sink": None,   # sink spec template: memory | jsonl:P | csv:P
}


def add_obs_flags(ap) -> None:
    """Attach the shared observability flags to an ArgumentParser."""
    ap.add_argument("--trace-out", default=None, metavar="DIR",
                    help="write Perfetto trace-event JSON files here")
    ap.add_argument("--metrics-sink", default=None, metavar="SPEC",
                    help="metric sink spec (memory | jsonl:PATH | csv:PATH; "
                         "file paths are suffixed per benchmark cell)")
    ap.add_argument("--debug-nans", action="store_true",
                    help="enable jax_debug_nans for this run")
    ap.add_argument("--x64", action="store_true",
                    help="enable 64-bit mode for this run")


def parse_cli_options(args) -> None:
    """Apply parsed obs flags: fill OPTIONS and flip debug toggles."""
    from repro.obs import debug
    OPTIONS["trace_out"] = args.trace_out
    OPTIONS["metrics_sink"] = args.metrics_sink
    if args.trace_out:
        os.makedirs(args.trace_out, exist_ok=True)
    if args.debug_nans:
        debug.set_debug_nan(True)
    if args.x64:
        debug.set_x64(True)


def cell_sink_spec(cell: str):
    """Per-cell sink spec from the global template.

    File-backed sinks get the cell name spliced in before the extension
    so concurrent cells don't clobber one file: ``jsonl:out.jsonl`` ->
    ``jsonl:out.<cell>.jsonl``.  Memory specs pass through unchanged.
    """
    spec = OPTIONS["metrics_sink"]
    if not spec:
        return None
    parts = []
    for s in spec.split(","):
        kind, _, arg = s.strip().partition(":")
        if arg:
            root, ext = os.path.splitext(arg)
            parts.append(f"{kind}:{root}.{cell}{ext or ''}")
        else:
            parts.append(s.strip())
    return ",".join(parts)


def trace_path(cell: str):
    """Trace file path for a benchmark cell, or None when tracing is off."""
    out = OPTIONS["trace_out"]
    if not out:
        return None
    return os.path.join(out, f"{cell}.trace.json")


def row(name: str, us_per_call: float, derived: dict) -> str:
    payload = json.dumps(derived, default=lambda x: round(float(x), 5)
                         if isinstance(x, (np.floating, float)) else str(x))
    return f"{name},{us_per_call:.1f},{payload}"


def tiny_cfg(n_layers=2, d_model=64, vocab=256):
    return get_config("llama-3.2-1b").reduced(n_layers=n_layers,
                                              d_model=d_model, vocab=vocab)


def make_spec(algorithm="firm", *, beta=0.05, n_clients=2, m=2,
              local_steps=1, batch=2, preference=None, seed=0,
              heterogeneous_rms=False, dirichlet_alpha=0.3,
              uplink_codec="identity", downlink_codec="identity",
              vectorized=True, fused_rounds=1, sched=None,
              metrics_sink=None, cfg=None) -> RunSpec:
    cfg = cfg or tiny_cfg()
    fc = FIRMConfig(n_objectives=m, n_clients=n_clients,
                    local_steps=local_steps, batch_size=batch, beta=beta,
                    preference=preference)
    ec = EngineConfig(algorithm=algorithm, max_new=8, prompt_len=4,
                      seed=seed, heterogeneous_rms=heterogeneous_rms,
                      dirichlet_alpha=dirichlet_alpha,
                      uplink_codec=uplink_codec,
                      downlink_codec=downlink_codec,
                      vectorized_clients=vectorized,
                      fused_rounds=fused_rounds,
                      metrics_sink=metrics_sink)
    return RunSpec(model=cfg, firm=fc, engine=ec, sched=sched)


def make_trainer(algorithm="firm", **kw):
    """RunSpec -> plan -> trainer.

    Returns a ``FederatedTrainer`` (or a ``ScheduledTrainer`` when
    ``sched=`` names a SchedConfig); the resolved ExecutionPlan rides
    along as ``.plan`` on the underlying trainer.
    """
    return api.plan(make_spec(algorithm, **kw)).build()


def timed_rounds(trainer, rounds: int):
    t0 = time.time()
    hist = trainer.run(rounds)
    us = (time.time() - t0) / rounds * 1e6
    return hist, us
