"""Shared helpers for the benchmark harness.

Every benchmark emits ``name,us_per_call,derived`` CSV rows (derived is a
compact json-ish summary of the paper-relevant quantities).

Trainers are built through the declarative front door
(``repro.fed.api``: RunSpec -> plan() -> build()), so every benchmark
cell runs exactly the executor the plan resolves — identical numbers to
direct ``FederatedTrainer`` construction (``tests/test_plan.py`` pins
this), with the plan available for inspection via ``trainer.plan``.
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.configs import get_config
from repro.configs.base import FIRMConfig
from repro.fed import api
from repro.fed.api import EngineConfig, RunSpec


def row(name: str, us_per_call: float, derived: dict) -> str:
    payload = json.dumps(derived, default=lambda x: round(float(x), 5)
                         if isinstance(x, (np.floating, float)) else str(x))
    return f"{name},{us_per_call:.1f},{payload}"


def tiny_cfg(n_layers=2, d_model=64, vocab=256):
    return get_config("llama-3.2-1b").reduced(n_layers=n_layers,
                                              d_model=d_model, vocab=vocab)


def make_spec(algorithm="firm", *, beta=0.05, n_clients=2, m=2,
              local_steps=1, batch=2, preference=None, seed=0,
              heterogeneous_rms=False, dirichlet_alpha=0.3,
              uplink_codec="identity", downlink_codec="identity",
              vectorized=True, fused_rounds=1, sched=None,
              cfg=None) -> RunSpec:
    cfg = cfg or tiny_cfg()
    fc = FIRMConfig(n_objectives=m, n_clients=n_clients,
                    local_steps=local_steps, batch_size=batch, beta=beta,
                    preference=preference)
    ec = EngineConfig(algorithm=algorithm, max_new=8, prompt_len=4,
                      seed=seed, heterogeneous_rms=heterogeneous_rms,
                      dirichlet_alpha=dirichlet_alpha,
                      uplink_codec=uplink_codec,
                      downlink_codec=downlink_codec,
                      vectorized_clients=vectorized,
                      fused_rounds=fused_rounds)
    return RunSpec(model=cfg, firm=fc, engine=ec, sched=sched)


def make_trainer(algorithm="firm", **kw):
    """RunSpec -> plan -> trainer.

    Returns a ``FederatedTrainer`` (or a ``ScheduledTrainer`` when
    ``sched=`` names a SchedConfig); the resolved ExecutionPlan rides
    along as ``.plan`` on the underlying trainer.
    """
    return api.plan(make_spec(algorithm, **kw)).build()


def timed_rounds(trainer, rounds: int):
    t0 = time.time()
    hist = trainer.run(rounds)
    us = (time.time() - t0) / rounds * 1e6
    return hist, us
