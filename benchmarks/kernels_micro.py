"""Kernel microbenchmarks: wall time of the XLA twin paths on CPU plus
oracle-agreement stats for the Pallas kernels (interpret mode).

On CPU these numbers measure the *jnp fallback* (what the dry-run lowers);
the Pallas kernels target TPU and are validated, not timed, here.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.kernels import ops, ref
from repro.models.attention import chunked_attention


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def bench_gram():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (3, 1 << 20))      # 3 x 1M-dim gradients
    jitted = jax.jit(ref.gram)
    us = _time(jitted, x)
    err = float(jnp.abs(ops.gram(x) - ref.gram(x)).max())
    return row("kernel_gram_3x1M", us,
               {"pallas_interpret_max_abs_err": err,
                "bytes_streamed_MB": x.size * 4 / 1e6})


def bench_attention():
    key = jax.random.PRNGKey(1)
    b, s, hq, hkv, dh = 1, 1024, 8, 2, 64
    q = jax.random.normal(key, (b, s, hq, dh), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, dh))
    fn = jax.jit(lambda q, k, v: chunked_attention(q, k, v, causal=True,
                                                   block=256))
    us = _time(fn, q, k, v)
    got = ops.flash_attention(q, k, v, causal=True, block_q=256,
                              block_k=256)
    err = float(jnp.abs(got - ref.flash_attention(q, k, v,
                                                  causal=True)).max())
    return row("kernel_flash_attention_1k", us,
               {"pallas_interpret_max_abs_err": err,
                "gqa_ratio": hq // hkv})


def bench_rmsnorm():
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (4096, 2048))
    g = jnp.ones((2048,))
    fn = jax.jit(ref.rmsnorm)
    us = _time(fn, x, g)
    err = float(jnp.abs(ops.rmsnorm(x, g) - ref.rmsnorm(x, g)).max())
    return row("kernel_rmsnorm_4096x2048", us,
               {"pallas_interpret_max_abs_err": err})


def bench_mgda_solver():
    from repro.core import mgda
    key = jax.random.PRNGKey(3)
    a = jax.random.normal(key, (3, 5))
    G = a @ a.T
    fn = jax.jit(lambda G: mgda.solve_qp_pgd(G, iters=100))
    us = _time(fn, G)
    return row("mgda_qp_pgd_100iters_M3", us, {})


def bench_quantize():
    """int8 codec hot path on a 1M-param flat delta (jnp fallback timed;
    Pallas interpret agreement reported)."""
    from repro.kernels.quantize import _DET_BITS
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (1024, 1024))
    bits = jnp.full(x.shape, _DET_BITS, jnp.uint32)
    fn = jax.jit(lambda x, b: ref.dequantize(*ref.quantize(x, b, 127)))
    us = _time(fn, x, bits)
    cp, sp = ops.quantize(x, bits, 127)
    cr, sr = ref.quantize(x, bits, 127)
    return row("kernel_quantize_int8_1M", us, {
        "codes_exact_match": bool((np.asarray(cp) == np.asarray(cr)).all()),
        "roundtrip_rel_err": float(
            jnp.linalg.norm(ref.dequantize(cr, sr) - x) / jnp.linalg.norm(x)),
        "bytes_out_vs_f32": round((cp.size + 4 * sp.size) / (4 * x.size), 4),
    })


def bench_topk_threshold():
    """Threshold-refinement top-k selection vs lax.top_k on 1M entries."""
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (1024, 1024))
    k = 10_000
    fn = jax.jit(lambda x: jax.lax.top_k(jnp.abs(x.reshape(-1)), k))
    us = _time(fn, x)
    lo, hi = ops.topk_threshold(x, k, use_pallas=False)
    cnt_lo = float(ref.abs_threshold_count(x, lo))
    cnt_hi = float(ref.abs_threshold_count(x, hi))
    return row("kernel_topk_threshold_1M_k10k", us, {
        "bracket_counts": [cnt_lo, cnt_hi], "k": k,
        "selection_exact": bool(cnt_hi < k <= cnt_lo),
    })


ALL = [bench_gram, bench_attention, bench_rmsnorm, bench_mgda_solver,
       bench_quantize, bench_topk_threshold]
