# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness entry point (deliverable d).

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only fig2,thm45
  PYTHONPATH=src python -m benchmarks.run --only sched --trace-out traces/ \
      --metrics-sink jsonl:metrics.jsonl             # telemetry exports

Groups:
  paper_figures  — Figs. 1-8 / RQ1-RQ3 / App. A experiments (toy scale)
  theory_checks  — Thm 4.5 drift scaling, Lemma F.6, linear speedup
  kernels_micro  — kernel microbenches + Pallas oracle agreement
  codec_tradeoff — reward-vs-measured-bytes Pareto sweep (comms codecs)
  round_throughput — loop vs vectorized round engine (rounds/sec, dispatches)
  sched_wallclock — scheduler policy x codec x heterogeneity wall-clock sweep
  roofline       — per-(arch x shape x mesh) roofline from the dry-run
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated substrings of bench names")
    from benchmarks import common
    common.add_obs_flags(ap)
    args = ap.parse_args()
    common.parse_cli_options(args)

    from benchmarks import (codec_tradeoff, compression_error, kernels_micro,
                            paper_figures, roofline_report,
                            round_throughput, sched_wallclock, theory_checks)
    benches = (paper_figures.ALL + theory_checks.ALL + kernels_micro.ALL +
               compression_error.ALL + codec_tradeoff.ALL +
               round_throughput.ALL + sched_wallclock.ALL +
               roofline_report.ALL)
    filters = [f for f in args.only.split(",") if f]

    print("name,us_per_call,derived")
    failures = 0
    for fn in benches:
        name = fn.__name__
        if filters and not any(f in name for f in filters):
            continue
        try:
            out = fn()
            if isinstance(out, list):
                for line in out:
                    print(line, flush=True)
            else:
                print(out, flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},0.0,{{\"error\": \"{type(e).__name__}: {e}\"}}",
                  flush=True)
            traceback.print_exc(file=sys.stderr)
    # per-pair roofline rows (compact, after the summary tables)
    if not filters or any("roofline" in f for f in filters):
        for line in roofline_report.bench_roofline_per_pair():
            print(line, flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
