"""Scheduler wall-clock sweep: policy x codec x heterogeneity preset.

Each cell runs ROUNDS server aggregations under a simulated clock
(repro.fed.sched) and reports the simulated seconds they took plus the
final rewards — the reward-vs-wall-clock data behind the scheduler's
headline claim: under bimodal (edge-vs-datacenter) heterogeneity the
synchronous barrier pays the slowest straggler every round, while the
deadline and fedbuff policies aggregate at the speed of the fast
majority.  Codec choice changes simulated time too (transmission time
derives from measured Payload bytes), so the sweep crosses policies
with the uplink codec.

Emits ``BENCH_sched_wallclock.json`` next to the CSV rows (CI uploads
it on main full runs, alongside the round-throughput baseline).  With
``--trace-out DIR`` each cell additionally exports its simulated-time
schedule as Perfetto trace-event JSON (one file per cell), and
``--metrics-sink jsonl:P`` streams each cell's metric records to
per-cell files.

  PYTHONPATH=src python -m benchmarks.run --only sched_wallclock
  PYTHONPATH=src python -m benchmarks.sched_wallclock      # standalone
  PYTHONPATH=src python -m benchmarks.sched_wallclock --trace-out traces/
"""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import cell_sink_spec, make_trainer, row, trace_path
from repro.configs.base import SchedConfig

POLICIES = ("sync", "deadline", "fedbuff")
CODECS = ("identity", "int8+ef")
PRESETS = ("homogeneous", "bimodal")
ROUNDS = 3
N_CLIENTS = 8


def _sched_config(policy: str, preset: str) -> SchedConfig:
    # the deadline quantile sits below bimodal's fast-client fraction
    # (0.25) so the deadline lands between the fast and slow modes and
    # actually cuts stragglers off; under homogeneous profiles all
    # predicted times are equal and nobody is dropped
    return SchedConfig(
        policy=policy, profile=preset, profile_seed=0,
        overselect=1.0, deadline_quantile=0.2,
        buffer_size=N_CLIENTS // 2, staleness_pow=0.5,
        staleness_beta_gain=0.5, staleness_bucket_max=2)


def _cell(policy: str, codec: str, preset: str) -> dict:
    name = f"sched_{preset}_{policy}_{codec.replace('+', '_')}"
    # RunSpec front door: sched= returns the ScheduledTrainer directly
    st = make_trainer("firm", beta=0.05, n_clients=N_CLIENTS,
                      local_steps=1, batch=2, uplink_codec=codec,
                      sched=_sched_config(policy, preset),
                      metrics_sink=cell_sink_spec(name))
    hist = st.run(ROUNDS)
    tp = trace_path(name)
    if tp:
        st.export_trace(tp)        # simulated-time Perfetto timeline
    st.obs.close()
    last = hist[-1]
    sim_time = float(last["sim_time"])
    rewards = np.asarray(last["rewards"], np.float64)
    return {
        "policy": policy, "codec": codec, "preset": preset,
        "sim_seconds_total": round(sim_time, 4),
        "sim_seconds_per_round": round(sim_time / ROUNDS, 4),
        "final_rewards": [round(float(r), 5) for r in rewards],
        "rewards_finite": bool(np.isfinite(rewards).all()),
        "dropped_total": int(sum(len(e.get("dropped", []))
                                 for e in hist)),
        "max_staleness": int(max((max(e["staleness"]) for e in hist
                                  if "staleness" in e), default=0)),
        "up_bytes": int(last["up_bytes"]),
    }


def bench_sched_wallclock():
    """The policy x codec x heterogeneity table + acceptance flags."""
    cells = [_cell(p, c, h)
             for h in PRESETS for c in CODECS for p in POLICIES]
    by = {(c["policy"], c["codec"], c["preset"]): c for c in cells}

    # acceptance: under bimodal heterogeneity, deadline and fedbuff
    # complete the same number of aggregations in less simulated time
    # than the synchronous barrier (reward-vs-wall-clock dominance)
    acceptance = {}
    for codec in CODECS:
        sync_t = by[("sync", codec, "bimodal")]["sim_seconds_total"]
        dl_t = by[("deadline", codec, "bimodal")]["sim_seconds_total"]
        fb_t = by[("fedbuff", codec, "bimodal")]["sim_seconds_total"]
        acceptance[codec] = {
            "sync_seconds": sync_t,
            "deadline_seconds": dl_t,
            "fedbuff_seconds": fb_t,
            "deadline_speedup": round(sync_t / max(dl_t, 1e-12), 3),
            "fedbuff_speedup": round(sync_t / max(fb_t, 1e-12), 3),
            "deadline_beats_sync": bool(dl_t < sync_t),
            "fedbuff_beats_sync": bool(fb_t < sync_t),
        }

    with open("BENCH_sched_wallclock.json", "w") as f:
        json.dump({"rounds": ROUNDS, "n_clients": N_CLIENTS,
                   "cells": cells, "acceptance": acceptance}, f, indent=2)

    rows = []
    for c in cells:
        rows.append(row(
            "sched_wallclock_"
            f"{c['preset']}_{c['policy']}_{c['codec']}",
            c["sim_seconds_per_round"] * 1e6, c))
    for codec, a in acceptance.items():
        rows.append(row(f"sched_wallclock_acceptance_{codec}", 0.0, a))
    return rows


ALL = [bench_sched_wallclock]


if __name__ == "__main__":
    import argparse

    from benchmarks import common

    ap = argparse.ArgumentParser()
    common.add_obs_flags(ap)
    common.parse_cli_options(ap.parse_args())
    print("name,us_per_call,derived")
    for fn in ALL:
        for line in fn():
            print(line, flush=True)
