"""Benchmarks validating the paper's THEORY claims numerically.

thm45_drift_scaling : the disagreement-drift term O(sqrt(M^3)/(beta sqrt(B)))
                      — lambda disagreement vs beta and vs batch size B
lemma_f6            : empirical certificate of the stability lemma
linear_speedup      : variance term O(1/(CB)) — gradient variance vs C*B
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.core import drift, mgda


def _noisy_client_lambdas(key, beta, batch, m=2, d=512, n_clients=8,
                          grad_noise=0.25):
    """Simulate clients estimating the same M gradients from B samples
    (noise ~ 1/sqrt(B)), each solving the regularized MGDA QP.

    Noise is kept << signal so the 1/(beta sqrt(B)) regime of Thm 4.5
    applies (at huge noise the noise itself inflates the Gram diagonal,
    which self-regularises and masks the trend)."""
    base = jax.random.normal(key, (m, d))
    base = base / jnp.linalg.norm(base, axis=1, keepdims=True)
    base = base.at[1].set(0.9 * base[0] + 0.45 * base[1])  # correlated
    lams = []
    for c in range(n_clients):
        noise = grad_noise / np.sqrt(batch) / np.sqrt(d) * \
            jax.random.normal(jax.random.fold_in(key, 100 + c), (m, d))
        G = mgda.gram_matrix(base + noise)
        lams.append(mgda.solve(G, beta, iters=300))
    return jnp.stack(lams)


def bench_thm45_drift_scaling():
    key = jax.random.PRNGKey(0)
    t0 = time.time()
    out = {"vs_beta": {}, "vs_batch": {}}
    for beta in (0.0, 0.01, 0.1, 1.0):
        ds = [float(drift.lambda_disagreement(
            _noisy_client_lambdas(jax.random.fold_in(key, s), beta, 16)
        )["pairwise_mean"]) for s in range(5)]
        out["vs_beta"][str(beta)] = float(np.mean(ds))
    for batch in (4, 16, 64, 256):
        ds = [float(drift.lambda_disagreement(
            _noisy_client_lambdas(jax.random.fold_in(key, 50 + s), 0.05,
                                  batch))["pairwise_mean"])
              for s in range(5)]
        out["vs_batch"][str(batch)] = float(np.mean(ds))
    b = out["vs_beta"]
    out["drift_decreases_with_beta"] = bool(b["1.0"] < b["0.0"])
    v = out["vs_batch"]
    out["drift_decreases_with_B"] = bool(v["256"] < v["4"])
    us = (time.time() - t0) * 1e6 / 40
    return row("thm45_drift_scaling", us, out)


def bench_lemma_f6_certificate():
    key = jax.random.PRNGKey(3)
    t0 = time.time()
    worst = 0.0
    m, d, beta = 3, 256, 0.2
    for i in range(20):
        k1, k2 = jax.random.split(jax.random.fold_in(key, i))
        g1 = [0.2 * jax.random.normal(jax.random.fold_in(k1, j), (d,))
              for j in range(m)]
        g2 = [a + 0.02 * jax.random.normal(jax.random.fold_in(k2, j), (d,))
              for j, a in enumerate(g1)]
        l1 = mgda.solve(mgda.gram_matrix(g1), beta, trace_normalize=False,
                        iters=500)
        l2 = mgda.solve(mgda.gram_matrix(g2), beta, trace_normalize=False,
                        iters=500)
        chk = drift.lemma_f6_check(g1, g2, l1, l2, beta)
        worst = max(worst, float(chk["lhs"] / (chk["rhs"] + 1e-12)))
    us = (time.time() - t0) * 1e6 / 20
    return row("lemma_f6_certificate", us,
               {"worst_lhs_over_rhs": worst, "bound_holds": worst <= 1.0})


def bench_linear_speedup_variance():
    """Variance of the AVERAGED client direction scales ~1/(C*B)."""
    key = jax.random.PRNGKey(9)
    t0 = time.time()
    d = 256

    def avg_dir_var(c, b, trials=20):
        dirs = []
        for t in range(trials):
            kt = jax.random.fold_in(key, t)
            per_client = []
            for ci in range(c):
                g = jnp.ones((2, d)) + (1.0 / np.sqrt(b)) * \
                    jax.random.normal(jax.random.fold_in(kt, ci), (2, d))
                lam = mgda.solve(mgda.gram_matrix(g), 0.05, iters=200)
                per_client.append(mgda.combine(g, lam))
            dirs.append(jnp.stack(per_client).mean(0))
        dirs = jnp.stack(dirs)
        return float(dirs.var(axis=0).sum())

    out = {}
    for c, b in ((1, 4), (4, 4), (1, 16), (4, 16)):
        out[f"C={c},B={b}"] = avg_dir_var(c, b)
    out["speedup_in_C"] = out["C=1,B=4"] / max(out["C=4,B=4"], 1e-12)
    out["speedup_in_B"] = out["C=1,B=4"] / max(out["C=1,B=16"], 1e-12)
    us = (time.time() - t0) * 1e6 / 80
    return row("thm45_linear_speedup_variance", us, out)


ALL = [bench_thm45_drift_scaling, bench_lemma_f6_certificate,
       bench_linear_speedup_variance]
