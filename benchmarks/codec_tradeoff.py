"""Reward-vs-bytes Pareto sweep: codecs x algorithms (FIRM's headline
communication-efficiency claim with a *real* codec layer instead of the
analytic model).

Each cell trains a smoke-scale federated run with the given uplink codec
and reports measured ledger bytes (Payload.nbytes, exact per dtype),
the analytic prediction, and the end-of-run rewards — the data behind an
accuracy-vs-bandwidth Pareto front (FedMOA-style heterogeneous-reward
deployments pick their operating point off this curve).

  PYTHONPATH=src python -m benchmarks.run --only codec
  PYTHONPATH=src python -m benchmarks.codec_tradeoff        # standalone
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import make_trainer, row
from repro.core import comms as comms_lib

CODECS = ("identity", "int8+ef", "int4+ef", "topk:0.05+ef", "lowrank:4+ef")
ALGORITHMS = ("firm", "fedcmoo")
ROUNDS = 2


def _sweep_cell(algorithm: str, codec: str, rounds: int = ROUNDS):
    tr = make_trainer(algorithm, uplink_codec=codec)
    t0 = time.time()
    hist = tr.run(rounds)
    us = (time.time() - t0) / rounds * 1e6
    last = hist[-1]
    return tr, us, {
        "rewards": np.asarray(last["rewards"]).tolist(),
        "up_bytes": int(last["up_bytes"]),
        "down_bytes": int(last["down_bytes"]),
        # the ExecutionPlan predicted these wire bytes BEFORE any
        # compilation (nbytes_static); the ledger must agree exactly
        "plan_up_bytes_per_round": int(tr.plan.up_bytes_per_round),
        "plan_matches_measured": bool(
            tr.plan.up_bytes_per_round * rounds == int(last["up_bytes"])),
    }


def bench_codec_tradeoff():
    """The headline table: measured uplink bytes + rewards per codec."""
    out = []
    base_up = {}
    for algorithm in ALGORITHMS:
        for codec in CODECS:
            tr, us, cell = _sweep_cell(algorithm, codec)
            key = algorithm
            if codec == "identity":
                base_up[key] = cell["up_bytes"]
            ratio = cell["up_bytes"] / max(1, base_up.get(key, 0))
            d = tr.d_trainable
            fc = tr.fc
            analytic = comms_lib.codec_bytes_per_param(codec, d) * d
            uploads_per_round = fc.n_clients
            if algorithm == "fedcmoo":      # M grads per step + the delta
                uploads_per_round *= fc.n_objectives * fc.local_steps + 1
            measured = cell["up_bytes"] / (ROUNDS * uploads_per_round)
            # ideal entropy-coded size of the run's final DELTA uplink
            # payloads (int4/topk codes are far from uniform, so this
            # quantifies the headroom a real range coder would buy at
            # identical fidelity).  Scope: the round's adapted-param
            # delta uploads only — fedcmoo's per-step gradient payloads
            # never land in _last_up_payloads, hence the explicit
            # "delta_upload" naming (headroom is raw/entropy over the
            # SAME payload set, so it stays self-consistent per cell)
            payloads = getattr(tr, "_last_up_payloads", None) or []
            ent = sum(p.nbytes_entropy for p in payloads)
            raw = sum(p.nbytes for p in payloads)
            cell.update({
                "codec": codec,
                "algorithm": algorithm,
                "uplink_ratio_vs_identity": round(ratio, 4),
                "analytic_bytes_per_upload": int(analytic),
                "measured_bytes_per_upload": int(measured),
                "padding_overhead": round(measured / analytic, 4),
                "entropy_bytes_per_delta_upload":
                    int(ent / max(1, len(payloads))),
                "entropy_headroom": round(raw / max(1, ent), 4),
            })
            out.append(row(f"codec_tradeoff_{algorithm}_{codec}", us, cell))
    return out


def bench_downlink_delta():
    """Downlink delta broadcast: same wire bytes as the inner codec,
    far lower distortion from round 2 on.

    The engine sweep measures down_bytes + training health; the
    distortion comparison quantizes a synthetic slowly-drifting param
    sequence (round-to-round deltas ~1% of the weights, like FedAvg
    updates) through int8 vs delta+int8 — the delta codec's per-block
    scale tracks the small delta instead of the full weight magnitude.
    """
    import jax
    import jax.numpy as jnp

    from repro.comms import make_codec, tree_to_flat

    cells = {}
    for down in ("identity", "int8", "delta+int8"):
        tr = make_trainer("firm", downlink_codec=down)
        hist = tr.run(2)
        cells[down] = {"down_bytes": int(hist[-1]["down_bytes"]),
                       "rewards_finite": bool(np.isfinite(np.asarray(
                           hist[-1]["rewards"])).all())}

    # distortion on a drifting sequence theta_t = theta_0 + sum of small
    # steps; report round-2+ mean relative error per codec
    key = jax.random.PRNGKey(0)
    theta = jax.random.normal(key, (32768,))
    spec = tree_to_flat({"w": theta})[1]
    plain, delta = make_codec("int8"), make_codec("delta+int8")
    st_p, st_d = None, None
    errs = {"int8": [], "delta+int8": []}
    flat = theta
    for t in range(1, 5):
        flat = flat + 0.01 * jax.random.normal(jax.random.fold_in(key, t),
                                               flat.shape)
        kp = jax.random.fold_in(key, 100 + t)
        _, st_p, dec_p = plain.roundtrip_flat(flat, spec, st_p, key=kp)
        _, st_d, dec_d = delta.roundtrip_flat(flat, spec, st_d, key=kp)
        nrm = float(jnp.linalg.norm(flat))
        errs["int8"].append(float(jnp.linalg.norm(dec_p - flat)) / nrm)
        errs["delta+int8"].append(float(jnp.linalg.norm(dec_d - flat))
                                  / nrm)
    tail_p = float(np.mean(errs["int8"][1:]))
    tail_d = float(np.mean(errs["delta+int8"][1:]))
    return row("codec_downlink_delta", 0.0, {
        **{f"down_bytes_{k}": v["down_bytes"] for k, v in cells.items()},
        "rewards_finite": bool(all(v["rewards_finite"]
                                   for v in cells.values())),
        "rel_err_int8": round(tail_p, 5),
        "rel_err_delta_int8": round(tail_d, 5),
        "distortion_ratio": round(tail_d / max(tail_p, 1e-12), 5),
        "delta_bytes_match_int8": bool(
            cells["delta+int8"]["down_bytes"]
            == cells["int8"]["down_bytes"]),
    })


def bench_codec_acceptance():
    """int8 uplink must be <= ~30% of identity at equal round count."""
    _, _, ident = _sweep_cell("firm", "identity")
    _, us, int8 = _sweep_cell("firm", "int8+ef")
    ratio = int8["up_bytes"] / ident["up_bytes"]
    return row("codec_int8_acceptance", us, {
        "identity_up_bytes": ident["up_bytes"],
        "int8_up_bytes": int8["up_bytes"],
        "ratio": round(ratio, 4),
        "meets_30pct_target": bool(ratio <= 0.30),
        "rewards_finite": bool(np.isfinite(np.asarray(
            int8["rewards"])).all()),
    })


ALL = [bench_codec_tradeoff, bench_downlink_delta, bench_codec_acceptance]


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for fn in ALL:
        res = fn()
        for line in (res if isinstance(res, list) else [res]):
            print(line, flush=True)
