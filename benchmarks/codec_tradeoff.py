"""Reward-vs-bytes Pareto sweep: codecs x algorithms (FIRM's headline
communication-efficiency claim with a *real* codec layer instead of the
analytic model).

Each cell trains a smoke-scale federated run with the given uplink codec
and reports measured ledger bytes (Payload.nbytes, exact per dtype),
the analytic prediction, and the end-of-run rewards — the data behind an
accuracy-vs-bandwidth Pareto front (FedMOA-style heterogeneous-reward
deployments pick their operating point off this curve).

  PYTHONPATH=src python -m benchmarks.run --only codec
  PYTHONPATH=src python -m benchmarks.codec_tradeoff        # standalone
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import make_trainer, row
from repro.core import comms as comms_lib

CODECS = ("identity", "int8+ef", "int4+ef", "topk:0.05+ef", "lowrank:4+ef")
ALGORITHMS = ("firm", "fedcmoo")
ROUNDS = 2


def _sweep_cell(algorithm: str, codec: str, rounds: int = ROUNDS):
    tr = make_trainer(algorithm, uplink_codec=codec)
    t0 = time.time()
    hist = tr.run(rounds)
    us = (time.time() - t0) / rounds * 1e6
    last = hist[-1]
    return tr, us, {
        "rewards": np.asarray(last["rewards"]).tolist(),
        "up_bytes": int(last["up_bytes"]),
        "down_bytes": int(last["down_bytes"]),
    }


def bench_codec_tradeoff():
    """The headline table: measured uplink bytes + rewards per codec."""
    out = []
    base_up = {}
    for algorithm in ALGORITHMS:
        for codec in CODECS:
            tr, us, cell = _sweep_cell(algorithm, codec)
            key = algorithm
            if codec == "identity":
                base_up[key] = cell["up_bytes"]
            ratio = cell["up_bytes"] / max(1, base_up.get(key, 0))
            d = tr.d_trainable
            fc = tr.fc
            analytic = comms_lib.codec_bytes_per_param(codec, d) * d
            uploads_per_round = fc.n_clients
            if algorithm == "fedcmoo":      # M grads per step + the delta
                uploads_per_round *= fc.n_objectives * fc.local_steps + 1
            measured = cell["up_bytes"] / (ROUNDS * uploads_per_round)
            cell.update({
                "codec": codec,
                "algorithm": algorithm,
                "uplink_ratio_vs_identity": round(ratio, 4),
                "analytic_bytes_per_upload": int(analytic),
                "measured_bytes_per_upload": int(measured),
                "padding_overhead": round(measured / analytic, 4),
            })
            out.append(row(f"codec_tradeoff_{algorithm}_{codec}", us, cell))
    return out


def bench_codec_acceptance():
    """int8 uplink must be <= ~30% of identity at equal round count."""
    _, _, ident = _sweep_cell("firm", "identity")
    _, us, int8 = _sweep_cell("firm", "int8+ef")
    ratio = int8["up_bytes"] / ident["up_bytes"]
    return row("codec_int8_acceptance", us, {
        "identity_up_bytes": ident["up_bytes"],
        "int8_up_bytes": int8["up_bytes"],
        "ratio": round(ratio, 4),
        "meets_30pct_target": bool(ratio <= 0.30),
        "rewards_finite": bool(np.isfinite(np.asarray(
            int8["rewards"])).all()),
    })


ALL = [bench_codec_tradeoff, bench_codec_acceptance]


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for fn in ALL:
        res = fn()
        for line in (res if isinstance(res, list) else [res]):
            print(line, flush=True)
