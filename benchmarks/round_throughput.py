"""Round-engine throughput: per-client loop vs vectorized round engine.

Measures rounds/sec and engine-level jitted dispatch counts for the firm
algorithm at C ∈ {4, 8, 16} on both paths, and emits a machine-readable
``BENCH_round_throughput.json`` next to the CSV rows (CI uploads it as an
artifact on main) — the baseline for the bench trajectory.

The loop path runs C × K × 3 jitted dispatches per round (generate, ref
logprobs, local step per client-step); the vectorized path fuses the
entire local phase into one scanned/vmapped jit, so at toy model sizes
rounds are dispatch-bound on the loop and compute-bound on the vmap.
"""
from __future__ import annotations

import json
import time

from benchmarks.common import make_trainer, row

CLIENT_COUNTS = (4, 8, 16)
LOCAL_STEPS = 2
TIMED_ROUNDS = 5


def _measure(vectorized: bool, n_clients: int) -> dict:
    tr = make_trainer("firm", n_clients=n_clients, m=2,
                      local_steps=LOCAL_STEPS, batch=2,
                      vectorized=vectorized)
    tr.run(1)                                   # compile/warmup round
    d0 = tr.jit_dispatches
    t0 = time.perf_counter()
    tr.run(TIMED_ROUNDS)
    dt = time.perf_counter() - t0
    return {
        "rounds_per_sec": TIMED_ROUNDS / dt,
        "us_per_round": dt / TIMED_ROUNDS * 1e6,
        "dispatches_per_round": (tr.jit_dispatches - d0) / TIMED_ROUNDS,
    }


def bench_round_throughput():
    results = {"algorithm": "firm", "local_steps": LOCAL_STEPS,
               "timed_rounds": TIMED_ROUNDS, "clients": {}}
    rows = []
    for c in CLIENT_COUNTS:
        loop = _measure(False, c)
        vec = _measure(True, c)
        speedup = loop["us_per_round"] / vec["us_per_round"]
        results["clients"][str(c)] = {
            "loop": loop, "vectorized": vec, "speedup": speedup}
        rows.append(row(
            f"round_throughput_c{c}", vec["us_per_round"],
            {"speedup": speedup,
             "loop_us": loop["us_per_round"],
             "vec_us": vec["us_per_round"],
             "loop_dispatches": loop["dispatches_per_round"],
             "vec_dispatches": vec["dispatches_per_round"]}))
    with open("BENCH_round_throughput.json", "w") as f:
        json.dump(results, f, indent=2)
    return rows


ALL = [bench_round_throughput]
