"""Round-engine throughput: per-client loop vs vectorized vs fused rounds.

Measures rounds/sec and engine-level jitted dispatch counts for the firm
algorithm at C ∈ {4, 8, 16} on all three paths, and emits a
machine-readable ``BENCH_round_throughput.json`` next to the CSV rows (CI
uploads it as an artifact on main) — the baseline for the bench
trajectory.

The loop path runs C × K × 3 jitted dispatches per round (generate, ref
logprobs, local step per client-step); the vectorized path fuses the
entire local phase into one scanned/vmapped jit but still pays Python
dispatch + a host transfer per round; the fused path
(``EngineConfig.fused_rounds``) wraps R whole rounds — participation,
codec roundtrips, aggregation included — in one round-level ``lax.scan``,
so a chunk of R rounds is O(1) dispatches and ONE host transfer.  At toy
model sizes rounds are dispatch-bound, which is exactly what the fused
path removes.
"""
from __future__ import annotations

import json
import time

from benchmarks.common import cell_sink_spec, make_trainer, row, trace_path
from repro.obs import TraceBuilder, jitwatch

CLIENT_COUNTS = (4, 8, 16)
# K=1, B=1: the communication-bound regime FIRM targets (a round IS
# cheap — one adapted-param upload), which is exactly where per-round
# driver overhead dominates and the fused scan pays off.  Heavier local
# phases (K=2, B=2) are compute-bound at toy scale and the three paths
# converge to kernel time.
LOCAL_STEPS = 1
BATCH = 1
TIMED_ROUNDS = 5
FUSED_R = 8          # rounds per fused chunk
FUSED_CHUNKS = 2     # timed chunks (R * CHUNKS rounds total)


def _measure(vectorized: bool, n_clients: int) -> dict:
    tr = make_trainer("firm", n_clients=n_clients, m=2,
                      local_steps=LOCAL_STEPS, batch=BATCH,
                      vectorized=vectorized)
    # the RunSpec front door resolved the executor this cell claims to
    # measure — a silent fallback would corrupt the benchmark
    want = "vectorized" if vectorized else "loop"
    assert tr.plan.executor == want, (tr.plan.executor, want)
    tr.run(1)                                   # compile/warmup round
    d0 = tr.jit_dispatches
    t0 = time.perf_counter()
    tr.run(TIMED_ROUNDS)
    dt = time.perf_counter() - t0
    return {
        "executor": tr.plan.executor,
        "rounds_per_sec": TIMED_ROUNDS / dt,
        "us_per_round": dt / TIMED_ROUNDS * 1e6,
        "dispatches_per_round": (tr.jit_dispatches - d0) / TIMED_ROUNDS,
    }


def _measure_fused(n_clients: int, r: int = FUSED_R) -> dict:
    name = f"round_throughput_fused_c{n_clients}"
    tr = make_trainer("firm", n_clients=n_clients, m=2,
                      local_steps=LOCAL_STEPS, batch=BATCH,
                      fused_rounds=r, metrics_sink=cell_sink_spec(name))
    assert tr.plan.executor == "fused", tr.plan.executor
    tr.run(r)                                   # compile/warmup chunk
    d0 = tr.jit_dispatches
    t0 = time.perf_counter()
    # record jit entries during the timed chunks so --trace-out can
    # render compile-vs-execute host wall-clock spans per program
    with jitwatch.record() as jlog:
        tr.run(r * FUSED_CHUNKS)
    dt = time.perf_counter() - t0
    tp = trace_path(name)
    if tp:
        tb = TraceBuilder()
        tb.add_host_spans(jlog.spans)
        tb.write(tp)
    tr.obs.close()
    rounds = r * FUSED_CHUNKS
    return {
        "executor": tr.plan.executor,
        "rounds": r,
        "rounds_per_sec": rounds / dt,
        "us_per_round": dt / rounds * 1e6,
        # O(1) per fused chunk: stack + fused program + unstack
        "dispatches_per_run": (tr.jit_dispatches - d0) / FUSED_CHUNKS,
    }


def bench_round_throughput():
    results = {"algorithm": "firm", "local_steps": LOCAL_STEPS,
               "batch_size": BATCH, "timed_rounds": TIMED_ROUNDS,
               "fused_rounds": FUSED_R, "clients": {}}
    rows = []
    for c in CLIENT_COUNTS:
        loop = _measure(False, c)
        vec = _measure(True, c)
        fused = _measure_fused(c)
        speedup = loop["us_per_round"] / vec["us_per_round"]
        fused_speedup = vec["us_per_round"] / fused["us_per_round"]
        results["clients"][str(c)] = {
            "loop": loop, "vectorized": vec, "fused": fused,
            "speedup": speedup, "fused_speedup_vs_vectorized": fused_speedup}
        rows.append(row(
            f"round_throughput_c{c}", vec["us_per_round"],
            {"speedup": speedup,
             "fused_speedup_vs_vec": fused_speedup,
             "loop_us": loop["us_per_round"],
             "vec_us": vec["us_per_round"],
             "fused_us": fused["us_per_round"],
             "loop_dispatches": loop["dispatches_per_round"],
             "vec_dispatches": vec["dispatches_per_round"],
             "fused_dispatches_per_run": fused["dispatches_per_run"]}))
    with open("BENCH_round_throughput.json", "w") as f:
        json.dump(results, f, indent=2)
    return rows


ALL = [bench_round_throughput]
