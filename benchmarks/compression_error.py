"""FedCMOO's compression trade-off (Askin et al.'s q-term, paper Rmk 4.6
comparison): how far the server's lambda drifts from the exact solution as
the gradient sketch rank shrinks — the error source FIRM eliminates by
never transmitting gradients at all.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.core import fedcmoo, mgda


def bench_fedcmoo_compression_error():
    key = jax.random.PRNGKey(0)
    d, m, n_clients = 100_000, 3, 4
    t0 = time.time()
    base = jax.random.normal(key, (m, d)) / np.sqrt(d)
    clients = [base + 0.1 / np.sqrt(d) * jax.random.normal(
        jax.random.fold_in(key, c), (m, d)) for c in range(n_clients)]
    exact = fedcmoo.server_solve(clients, beta=0.0)
    out = {"d": d, "exact_lambda": np.asarray(exact).tolist(), "vs_rank": {}}
    for rank in (100, 1000, 10000):
        errs = []
        for s in range(5):
            kk = jax.random.fold_in(key, 1000 + s)
            sk = [fedcmoo.sketch(c, rank, kk) for c in clients]
            lam = fedcmoo.server_solve(sk, beta=0.0)
            errs.append(float(jnp.linalg.norm(lam - exact)))
        out["vs_rank"][str(rank)] = float(np.mean(errs))
    v = out["vs_rank"]
    out["error_decreases_with_rank"] = bool(v["10000"] < v["100"])
    out["firm_error"] = 0.0    # FIRM transmits no gradients: no q-term
    us = (time.time() - t0) * 1e6 / 16
    return row("fedcmoo_compression_q_term", us, out)


ALL = [bench_fedcmoo_compression_error]
