"""One benchmark per paper figure/table (§5 + App. A), at CPU toy scale.

Fig. 2  (RQ1): FIRM vs FedCMOO — rewards + lambda smoothness + comm bytes
Fig. 3  (RQ2): beta=0 vs beta>0 — disagreement drift + rewards
Fig. 4  (RQ3): preference sweep -> Pareto trade-off points
Fig. 5/6     : homogeneous vs heterogeneous reward models
Fig. 7 (A.2.2): client scaling (2 vs 4 clients here)
Fig. 8 (A.2.3): M=3 objectives, FIRM vs FedCMOO
Fig. 1 (comms): O(Cd) vs O(CMd) measured + analytic bytes
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import make_trainer, row, timed_rounds
from repro.core import comms

ROUNDS = 3


def bench_rq1_firm_vs_fedcmoo():
    out = {}
    us = 0.0
    for alg in ("firm", "fedcmoo"):
        tr = make_trainer(alg, local_steps=2)
        hist, us_ = timed_rounds(tr, ROUNDS)
        us = max(us, us_)
        lam_path = np.stack([h["lam_mean"] for h in hist])
        out[alg] = {
            "final_rewards": hist[-1]["rewards"].tolist(),
            "lam_osc": float(np.abs(np.diff(lam_path[:, 0])).mean()),
            "comm_MB": tr.ledger.total / 1e6,
        }
    out["comm_ratio_fedcmoo_over_firm"] = \
        out["fedcmoo"]["comm_MB"] / out["firm"]["comm_MB"]
    return row("fig2_rq1_firm_vs_fedcmoo", us, out)


def bench_rq2_regularization():
    out = {}
    us = 0.0
    for name, alg in (("beta_0.05", "firm"), ("beta_0", "firm_unreg")):
        tr = make_trainer(alg, beta=0.05)
        hist, us_ = timed_rounds(tr, ROUNDS)
        us = max(us, us_)
        out[name] = {
            "lam_disagreement": float(np.mean(
                [h["lam_disagreement"] for h in hist])),
            "final_rewards": hist[-1]["rewards"].tolist(),
        }
    return row("fig3_rq2_regularization_ablation", us, out)


def bench_rq3_preference_pareto():
    points = []
    us = 0.0
    for p0 in (0.25, 1.0, 4.0):
        tr = make_trainer("firm", preference=(p0, 1.0 / p0), seed=1)
        hist, us_ = timed_rounds(tr, ROUNDS)
        us = max(us, us_)
        points.append({"preference": [p0, round(1.0 / p0, 3)],
                       "rewards": hist[-1]["rewards"].tolist(),
                       "lam_mean": hist[-1]["lam_mean"].tolist()})
    lam0 = [pt["lam_mean"][0] for pt in points]
    return row("fig4_rq3_preference_pareto", us,
               {"points": points,
                "lam0_monotone_in_pref": bool(lam0[0] <= lam0[-1])})


def bench_hetero_reward_models():
    out = {}
    us = 0.0
    for name, het in (("same_rms", False), ("different_rms", True)):
        tr = make_trainer("firm", heterogeneous_rms=het, n_clients=2)
        hist, us_ = timed_rounds(tr, ROUNDS)
        us = max(us, us_)
        out[name] = {
            "lam_mean": hist[-1]["lam_mean"].tolist(),
            "final_rewards": hist[-1]["rewards"].tolist(),
            "lam_disagreement": float(np.mean(
                [h["lam_disagreement"] for h in hist])),
        }
    return row("fig5_heterogeneous_rms", us, out)


def bench_client_scaling():
    out = {}
    us = 0.0
    for c in (2, 4):
        tr = make_trainer("firm", n_clients=c)
        hist, us_ = timed_rounds(tr, ROUNDS)
        us = max(us, us_)
        out[f"C={c}"] = {
            "lam_mean": hist[-1]["lam_mean"].tolist(),
            "final_rewards": hist[-1]["rewards"].tolist(),
        }
    return row("fig7_client_scaling", us, out)


def bench_three_objectives():
    out = {}
    us = 0.0
    for alg in ("firm", "fedcmoo"):
        tr = make_trainer(alg, m=3)
        hist, us_ = timed_rounds(tr, ROUNDS)
        us = max(us, us_)
        out[alg] = {"final_rewards": hist[-1]["rewards"].tolist(),
                    "lam_mean": hist[-1]["lam_mean"].tolist()}
    return row("fig8_three_objectives", us, out)


def bench_comms_table():
    """Fig. 1: analytic bytes at the paper's production scale (LoRA on
    Llama-3.2-1B: d ~= 2.3M adapter params) + the measured toy ledger."""
    d = 2_300_000
    table = {}
    for m in (2, 3):
        f = comms.firm_round_bytes(d, n_clients=8, local_steps=3)
        s = comms.fedcmoo_round_bytes(d, n_clients=8, n_objectives=m,
                                      local_steps=3)
        sc = comms.fedcmoo_round_bytes(d, n_clients=8, n_objectives=m,
                                       local_steps=3, compress_rank=50000)
        table[f"M={m}"] = {
            "firm_MB": f["total"] / 1e6,
            "fedcmoo_MB": s["total"] / 1e6,
            "fedcmoo_compressed_MB": sc["total"] / 1e6,
            "ratio": s["total"] / f["total"],
        }
    tr_f = make_trainer("firm", local_steps=2)
    tr_f.run(1)
    tr_s = make_trainer("fedcmoo", local_steps=2)
    tr_s.run(1)
    table["measured_toy_ratio"] = tr_s.ledger.total / tr_f.ledger.total
    return row("fig1_comms_table", 0.0, table)


ALL = [bench_rq1_firm_vs_fedcmoo, bench_rq2_regularization,
       bench_rq3_preference_pareto, bench_hetero_reward_models,
       bench_client_scaling, bench_three_objectives, bench_comms_table]
