"""CI-facing bench reporting: trajectory tables, smoke audits, sample trace.

Three subcommands (combinable), all dependency-free:

  --table [DIR]     parse the ``BENCH_*.json`` artifacts the benchmarks
                    emit (round_throughput, sched_wallclock) into
                    markdown trajectory tables on stdout — what the CI
                    job appends to its step summary on main
  --smoke           fast-lane plan audit: run the firm x {identity,
                    int8+ef} x {per-round, fused} matrix at toy scale
                    through ``repro.obs.audit_run`` and exit nonzero on
                    any predicted-vs-observed drift (dispatch counts,
                    wire bytes, post-warmup recompiles)
  --trace-out PATH  export a sample simulated-time Perfetto trace
                    (bimodal heterogeneity, deadline policy) that CI
                    uploads as an artifact — open at ui.perfetto.dev

  PYTHONPATH=src python -m benchmarks.bench_report --smoke
  PYTHONPATH=src python -m benchmarks.bench_report --table .
  PYTHONPATH=src python -m benchmarks.bench_report --trace-out sample.json
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

AUDIT_CODECS = ("identity", "int8+ef")
AUDIT_EXECUTORS = ("per-round", "fused")   # per-round == vectorized


# ------------------------------------------------------------------ table
def _md_table(headers, rows) -> str:
    lines = ["| " + " | ".join(headers) + " |",
             "|" + "|".join("---" for _ in headers) + "|"]
    for r in rows:
        lines.append("| " + " | ".join(str(x) for x in r) + " |")
    return "\n".join(lines)


def _round_throughput_table(data: dict) -> str:
    rows = []
    for c, cell in sorted(data.get("clients", {}).items(), key=lambda kv:
                          int(kv[0])):
        rows.append([
            c,
            f"{cell['loop']['us_per_round']:.0f}",
            f"{cell['vectorized']['us_per_round']:.0f}",
            f"{cell['fused']['us_per_round']:.0f}",
            f"{cell['speedup']:.2f}x",
            f"{cell['fused_speedup_vs_vectorized']:.2f}x",
            f"{cell['vectorized']['dispatches_per_round']:.0f}",
            f"{cell['fused']['dispatches_per_run']:.0f}",
        ])
    return "### round throughput (us/round)\n\n" + _md_table(
        ["clients", "loop", "vectorized", "fused", "vec speedup",
         "fused speedup", "vec disp/round", "fused disp/chunk"], rows)


def _sched_wallclock_table(data: dict) -> str:
    rows = []
    for c in data.get("cells", []):
        rows.append([
            c["preset"], c["policy"], c["codec"],
            f"{c['sim_seconds_total']:.4f}",
            c["dropped_total"], c["max_staleness"],
        ])
    out = ["### scheduler simulated wall-clock "
           f"({data.get('rounds')} rounds, {data.get('n_clients')} clients)",
           "", _md_table(["preset", "policy", "codec", "sim seconds",
                          "dropped", "max staleness"], rows)]
    acc = data.get("acceptance", {})
    if acc:
        arows = [[codec, a["sync_seconds"], a["deadline_seconds"],
                  a["fedbuff_seconds"], f"{a['deadline_speedup']}x",
                  f"{a['fedbuff_speedup']}x"]
                 for codec, a in sorted(acc.items())]
        out += ["", _md_table(["codec", "sync s", "deadline s", "fedbuff s",
                               "deadline speedup", "fedbuff speedup"],
                              arows)]
    return "\n".join(out)


_TABLES = {
    "BENCH_round_throughput.json": _round_throughput_table,
    "BENCH_sched_wallclock.json": _sched_wallclock_table,
}


def report_tables(bench_dir: str) -> int:
    """Render every known BENCH_*.json under ``bench_dir``; returns the
    number of artifacts rendered (0 is not an error — a fast-lane run
    may not have produced any)."""
    found = 0
    for path in sorted(glob.glob(os.path.join(bench_dir, "BENCH_*.json"))):
        base = os.path.basename(path)
        fmt = _TABLES.get(base)
        with open(path) as f:
            data = json.load(f)
        if fmt is None:
            print(f"### {base}\n\n```json\n"
                  + json.dumps(data, indent=1)[:2000] + "\n```\n")
        else:
            print(fmt(data) + "\n")
        found += 1
    if not found:
        print(f"(no BENCH_*.json artifacts under {bench_dir!r})")
    return found


# ------------------------------------------------------------------ smoke
def smoke_audit() -> int:
    """The plan-audit matrix; returns the number of failed cells."""
    from benchmarks.common import make_trainer
    from repro.obs import PlanDriftError, audit_run

    failures = 0
    for codec in AUDIT_CODECS:
        for executor in AUDIT_EXECUTORS:
            fused = 2 if executor == "fused" else 1
            tr = make_trainer("firm", n_clients=2, m=2, local_steps=1,
                              batch=2, uplink_codec=codec,
                              fused_rounds=fused)
            tag = f"audit firm/{executor}/{codec}"
            try:
                report = audit_run(tr).raise_on_drift()
            except PlanDriftError as e:
                failures += 1
                print(f"FAIL {tag}\n{e}", flush=True)
                continue
            checks = {c.name: c.observed for c in report.checks}
            print(f"ok   {tag}: {json.dumps(checks)}", flush=True)
    return failures


# ------------------------------------------------------------------ trace
def sample_trace(path: str) -> None:
    """Bimodal-heterogeneity deadline run -> Perfetto trace at ``path``."""
    from benchmarks.common import make_trainer
    from repro.configs.base import SchedConfig

    st = make_trainer("firm", n_clients=8, local_steps=1, batch=2,
                      sched=SchedConfig(policy="deadline",
                                        profile="bimodal", profile_seed=0,
                                        overselect=1.0,
                                        deadline_quantile=0.2))
    st.run(3)
    st.export_trace(path)
    print(f"wrote sample deadline/bimodal trace -> {path}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--table", nargs="?", const=".", default=None,
                    metavar="DIR", help="render BENCH_*.json tables")
    ap.add_argument("--smoke", action="store_true",
                    help="run the plan-audit smoke matrix")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a sample Perfetto trace here")
    args = ap.parse_args()
    if not (args.table or args.smoke or args.trace_out):
        ap.error("nothing to do: pass --table, --smoke and/or --trace-out")

    failures = 0
    if args.smoke:
        failures += smoke_audit()
    if args.trace_out:
        sample_trace(args.trace_out)
    if args.table:
        report_tables(args.table)
    if failures:
        print(f"{failures} audit cell(s) drifted", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
